//! Bench: Data-aware 3D Parallelism Optimizer (paper Fig 16a).
//!
//! Target: < 200 ms at 1024 GPUs / GBS 2048 (the paper's "negligible even
//! for large clusters" claim).
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::optimizer::batch::{candidate_tables, eval_candidates, eval_candidates_serial};
use dflop::optimizer::plan::{ModPar, Theta};
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};

fn main() {
    let m = llava_ov(llama3("8b"));
    let mut backend = SimBackend::new(Truth::new(ClusterSpec::hgx_a100(1)));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(42);
    let data = profile_data(&m, &mut ds, 256);
    println!("== optimizer_bench (Fig 16a) ==");
    let mut results = Vec::new();
    for &(gpus, gbs) in &[(64usize, 512usize), (256, 1024), (1024, 2048)] {
        let inp = OptimizerInputs {
            m: &m,
            profile: &profile,
            data: &data,
            n_gpus: gpus,
            gpus_per_node: 8,
            mem_capacity: ClusterSpec::hgx_a100(1).gpu.mem_bytes,
            gbs,
            assume_balanced: true,
        };
        results.push(bench(&format!("optimize gpus={gpus} gbs={gbs}"), 3, || {
            let r = optimize(&inp).expect("feasible");
            std::hint::black_box(r.theta);
        }));
    }

    // Refinement evaluator pair: the same 48-candidate θ sweep scored one
    // full pipeline sim per candidate (serial oracle) vs through the
    // batched evaluator (shared cost tables + delta-replayed re-pricing
    // within a structure group). Read by name in `dflop-bench-compare`.
    let inp = OptimizerInputs {
        m: &m,
        profile: &profile,
        data: &data,
        n_gpus: 64,
        gpus_per_node: 8,
        mem_capacity: ClusterSpec::hgx_a100(1).gpu.mem_bytes,
        gbs: 512,
        assume_balanced: true,
    };
    let mut cands: Vec<Theta> = Vec::new();
    for &l_tp in &[1usize, 2, 4] {
        for l_pp in 1..=4usize {
            for &n_mb in &[4usize, 8, 16, 32] {
                cands.push(Theta {
                    enc: ModPar { tp: 1, pp: 1, dp: 2 },
                    llm: ModPar { tp: l_tp, pp: l_pp, dp: 1 },
                    n_mb,
                });
            }
        }
    }
    results.push(bench("refine 48 candidates, serial (gbs 512)", 5, || {
        let (keys, tables) = candidate_tables(&inp, &cands);
        std::hint::black_box(eval_candidates_serial(&inp, &keys, &tables, &cands));
    }));
    results.push(bench("refine 48 candidates, batched (gbs 512)", 5, || {
        let (keys, tables) = candidate_tables(&inp, &cands);
        std::hint::black_box(eval_candidates(&inp, &keys, &tables, &cands));
    }));
    common::emit_json("optimizer_bench", &results);
}
