//! Bench: the PR-10 acceptance pair — plain DFLOP vs the bubble-filling
//! interleaved execution model on a video-heavy mixture.
//!
//! The gated rows are *simulated* seconds lifted from paired `run_system`
//! calls sharing one seed, model, dataset, and (provably optimal) ILP
//! regime, so `dflop-bench-compare` can enforce the acceptance claims as
//! exactly reproducible in-binary ratios: the interleaved mean step must
//! be ≤ 0.999× the plain step, and the mean `obs::bubble` iteration
//! bubble fraction strictly lower. The wall-clock row prices the fill
//! pass itself (measure → shrink → pack → re-simulate) so its overhead
//! stays visible next to the plain iteration cost it rides on.
mod common;
use common::{bench, BenchResult};
use dflop::model::catalog::{internvl_25, qwen25};
use dflop::obs::bubble::iteration_bubble_fraction;
use dflop::sim::{run_system, RunConfig, RunResult, SystemKind};
use std::time::Duration;

/// The acceptance configuration shared with
/// `sim::trainer`'s `interleaved_beats_plain_dflop_on_video_heavy_mixture`
/// test: InternVL's 6B encoder on the video mixture, small batches + a
/// 10 s ILP budget so every scheduling call proves optimality (a
/// budget-expired incumbent would make the paired ratio wall-clock
/// dependent).
fn pair_cfg() -> RunConfig {
    let iters = if common::quick() { 2 } else { 4 };
    let mut cfg = RunConfig::new(2, 16, iters, 42);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);
    cfg
}

/// A simulated-seconds row: the value is model output, not wall-clock,
/// so one rep with mean = min = max.
fn simulated(name: &str, v: f64) -> BenchResult {
    println!("{name:56} simulated {v:.6} s");
    BenchResult { name: name.to_string(), mean: v, min: v, max: v, reps: 1 }
}

fn mean_bubble_fraction(r: &RunResult) -> f64 {
    let fracs: Vec<f64> = r.iterations.iter().map(iteration_bubble_fraction).collect();
    fracs.iter().sum::<f64>() / fracs.len().max(1) as f64
}

fn main() {
    println!("== interleave_bench ==");
    let mut results = Vec::new();

    let m = internvl_25(qwen25("7b"));
    let cfg = pair_cfg();
    let plain = run_system(SystemKind::Dflop, &m, "video", &cfg);
    let inter = run_system(SystemKind::DflopInterleaved, &m, "video", &cfg);
    assert_eq!(plain.lpt_fallbacks, 0, "ILP budget expired — shrink the pair instance");
    assert_eq!(inter.lpt_fallbacks, 0, "ILP budget expired — shrink the pair instance");
    assert_eq!(inter.theta, plain.theta, "the fill pass must not change the plan");
    assert!(
        inter.iterations.iter().any(|s| !s.fills.is_empty()),
        "fill pass never placed a sub-op — the paired rows would gate nothing"
    );

    results.push(simulated(
        "mean step, interleaved (video, InternVL 6B enc)",
        inter.mean_iteration_time,
    ));
    results.push(simulated(
        "mean step, plain dflop (video, InternVL 6B enc)",
        plain.mean_iteration_time,
    ));
    results.push(simulated(
        "bubble fraction, interleaved (video, InternVL 6B enc)",
        mean_bubble_fraction(&inter),
    ));
    results.push(simulated(
        "bubble fraction, plain dflop (video, InternVL 6B enc)",
        mean_bubble_fraction(&plain),
    ));

    // Wall-clock cost of the fill pass: one full interleaved run vs one
    // plain run over the same draws (informational, not gated — the
    // pass re-simulates the pipeline a handful of times per iteration).
    results.push(bench("run 1 plain iteration set (video, gbs 16)", 5, || {
        let mut c = pair_cfg();
        c.iters = 1;
        std::hint::black_box(run_system(SystemKind::Dflop, &m, "video", &c).iterations.len());
    }));
    results.push(bench("run 1 interleaved iteration set (video, gbs 16)", 5, || {
        let mut c = pair_cfg();
        c.iters = 1;
        std::hint::black_box(
            run_system(SystemKind::DflopInterleaved, &m, "video", &c).iterations.len(),
        );
    }));

    common::emit_json("interleave_bench", &results);
}
