//! Bench: engine-loop overhead vs the old inlined training loops.
//!
//! The PR-5 engine routes every iteration through two trait objects
//! (`PlanPolicy`, `ExecModel`) and the `Telemetry` collector instead of a
//! hand-rolled loop body. That seam must cost nothing measurable next to
//! the work it dispatches (scheduling + 1F1B sims), so each pair below
//! runs the *same* per-iteration arithmetic once through the engine types
//! and once hand-inlined the way `sim::trainer` used to write it. Both
//! sides share the offline artifacts; the deltas are dynamic dispatch,
//! the `Draw`/`Scheduled` wrappers, and telemetry recording.

mod common;
use common::bench;
use dflop::baselines::homogeneous::random_buckets;
use dflop::data::dataset::Dataset;
use dflop::data::item::ItemShape;
use dflop::engine::exec::{ExecModel, ShardedExec, SingleReplicaExec};
use dflop::engine::policy::{PlanPolicy, StaticPolicy};
use dflop::engine::telemetry::Telemetry;
use dflop::engine::{DataFeed, Draw};
use dflop::model::catalog::{llama3, llava_ov};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::pipeline::build::{iterate_ws, SystemPlan};
use dflop::pipeline::sim::SimWorkspace;
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{ModelProfiler, ProfilerGrids};
use dflop::profiling::estimator::Estimator;
use dflop::shard::partition::ShardedDataset;
use dflop::shard::sync::{cross_shard_allreduce, lpt_shard_buckets, simulate_shards, step_barrier};
use dflop::shard::ShardConfig;
use dflop::sim::{RunConfig, SystemKind};
use dflop::util::rng::Rng;

fn main() {
    println!("== engine_bench ==");
    let mut results = Vec::new();
    let m = llava_ov(llama3("8b"));
    let cluster = ClusterSpec::hgx_a100(1);
    let truth = Truth::new(cluster);
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let est = Estimator::new(&m, &profile.throughput);
    let theta = dflop::optimizer::plan::Theta {
        enc: dflop::optimizer::plan::ModPar { tp: 1, pp: 1, dp: 1 },
        llm: dflop::optimizer::plan::ModPar { tp: 1, pp: 7, dp: 1 },
        n_mb: 8,
    };
    let iters = if common::quick() { 8 } else { 32 };
    let gbs = 64;
    let cfg = RunConfig::new(1, gbs, iters, 42);

    // ---- single replica: engine seam vs inlined loop ----
    // Megatron-style (random partitioner) keeps both sides budget-free so
    // the comparison measures the seam, not ILP wall-clock noise.
    results.push(bench(
        &format!("engine loop: {iters} single-replica iterations (gbs {gbs})"),
        10,
        || {
            let mut feed =
                DataFeed::single(Dataset::by_key("mixed", cfg.seed).expect("key"), gbs);
            let mut policy = StaticPolicy;
            let mut exec =
                SingleReplicaExec::new(SystemKind::Megatron, &m, &truth, &est, theta, &cfg);
            let mut tel = Telemetry::new(iters);
            for _ in 0..iters {
                let draw = feed.draw(&m);
                if let Some(plan) = policy.observe(&draw) {
                    exec.apply_plan(&plan);
                }
                let sched = exec.schedule(&draw, &mut tel);
                let stats = exec.execute(&sched, &mut tel);
                exec.correct(&sched, &stats);
                tel.record_iteration(stats);
            }
            std::hint::black_box(tel.iterations.len());
        },
    ));
    results.push(bench(
        &format!("inlined loop: {iters} single-replica iterations (gbs {gbs})"),
        10,
        || {
            let mut ds = Dataset::by_key("mixed", cfg.seed).expect("key");
            let mut rng = Rng::new(cfg.seed ^ 0xB0CC);
            let mut ws = SimWorkspace::new();
            let mut iterations = Vec::with_capacity(iters);
            let mut stage_thr = Vec::new();
            for _ in 0..iters {
                let shapes = ds.shaped_batch(&m, gbs);
                let buckets = random_buckets(&shapes, theta.buckets(), &mut rng);
                let plan = SystemPlan { m: &m, truth: &truth, theta };
                let stats = iterate_ws(&plan, &buckets, &mut ws);
                stage_thr.extend(stats.stage_throughputs());
                iterations.push(stats);
            }
            std::hint::black_box(iterations.len());
        },
    ));

    // ---- sharded step: engine seam vs inlined fan-out ----
    let shards = 4;
    // Rebalancing off so both sides run the identical static step — the
    // migration walk would only run on the engine side and mask the seam
    // cost being measured.
    let sc = ShardConfig {
        dp_shards: shards,
        window_batches: 4,
        rebalance: false,
        ..ShardConfig::default()
    };
    let counts = ShardedDataset::split_counts(gbs, shards);
    let steps = if common::quick() { 4 } else { 12 };
    results.push(bench(
        &format!("engine loop: {steps} sharded steps ({shards} replicas, gbs {gbs})"),
        10,
        || {
            let mut feed = DataFeed::sharded(
                ShardedDataset::by_key("skewed-shard", shards, cfg.seed).expect("key"),
                counts.clone(),
            );
            let mut exec = ShardedExec::new(&m, &truth, &est, theta, &sc);
            let mut tel = Telemetry::new(steps);
            for _ in 0..steps {
                let draw = feed.draw(&m);
                let sched = exec.schedule(&draw, &mut tel);
                let stats = exec.execute(&sched, &mut tel);
                tel.record_iteration(stats);
            }
            std::hint::black_box(tel.migrations);
        },
    ));
    results.push(bench(
        &format!("inlined loop: {steps} sharded steps ({shards} replicas, gbs {gbs})"),
        10,
        || {
            let mut sd =
                ShardedDataset::by_key("skewed-shard", shards, cfg.seed).expect("key");
            let mut gate = dflop::shard::agg::ShardWindows::new(shards, sc.window_batches);
            let mut iterations = Vec::with_capacity(steps);
            let mut gaps = Vec::with_capacity(steps);
            for _ in 0..steps {
                let batches = sd.shard_batches(&m, &counts);
                gate.push(
                    batches
                        .iter()
                        .map(|b| dflop::stream::window::ShapeStats::of_batch(b))
                        .collect(),
                );
                let buckets: Vec<Vec<Vec<ItemShape>>> = batches
                    .iter()
                    .map(|b| lpt_shard_buckets(&est, theta, b))
                    .collect();
                let per = simulate_shards(&m, &truth, theta, &buckets);
                let barrier = step_barrier(
                    per.iter().map(|s| s.iteration_time).collect(),
                    cross_shard_allreduce(&m, &truth, theta, shards),
                );
                gaps.push(barrier.straggler_gap);
                iterations.push(per);
            }
            std::hint::black_box(gaps.len());
        },
    ));

    common::emit_json("engine_bench", &results);
}
