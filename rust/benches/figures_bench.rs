//! Bench: wall-clock of every figure regeneration (one per paper
//! table/figure). The whole evaluation section must regenerate in minutes.
mod common;
use common::bench;
use dflop::figures::{by_id, FigOpts};

fn main() {
    println!("== figures_bench (per-figure regeneration cost) ==");
    let mut o = FigOpts::default();
    o.iters = 3;
    for id in ["1", "2", "4", "7", "8", "9", "10", "11", "12", "13", "14", "15", "16"] {
        bench(&format!("figure {id}"), 1, || {
            std::hint::black_box(by_id(id, &o).expect("figure id").len());
        });
    }
}
