//! Bench: wall-clock of every figure regeneration (one per paper
//! table/figure). The whole evaluation section must regenerate in minutes;
//! the system-level figures sweep their evaluation grids on the
//! `util::parallel` pool, so these numbers scale with the core count.
mod common;
use common::{bench, quick};
use dflop::figures::{by_id, FigOpts};

fn main() {
    println!("== figures_bench (per-figure regeneration cost) ==");
    // Quick mode (CI smoke): tiny experiment scale and the cheap figures
    // only, so the target finishes in seconds while still exercising the
    // pipeline, grid, and timeline layers.
    let (o, ids): (FigOpts, &[&str]) = if quick() {
        (
            FigOpts { nodes: 1, gbs: 32, iters: 2, seed: 42 },
            &["1", "2", "4", "13"],
        )
    } else {
        (
            FigOpts { iters: 3, ..FigOpts::default() },
            &["1", "2", "4", "7", "8", "9", "10", "11", "12", "13", "14", "15", "16", "drift"],
        )
    };
    let mut results = Vec::new();
    for id in ids {
        results.push(bench(&format!("figure {id}"), 1, || {
            std::hint::black_box(by_id(id, &o).expect("figure id").len());
        }));
    }
    common::emit_json("figures_bench", &results);
}
