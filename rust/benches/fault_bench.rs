//! Bench: the fault subsystem's per-step costs plus the PR-7 acceptance
//! pair — static θ* vs degradation-aware replanning under the *same*
//! deterministic skewed-churn `FaultTrace`.
//!
//! The real-time rows cover the machinery that runs at every iteration
//! boundary of a fleet run (trace generation, `FleetState::advance`, the
//! slowdown-weighted batch split): all must be negligible next to a
//! pipeline sim. The `fleet mean step` / `fleet worst straggler gap` rows
//! are *simulated* seconds lifted from paired `run_system` calls — both
//! arms replay the identical trace, so `dflop-bench-compare` can gate the
//! acceptance claims (aware strictly faster, aware strictly smaller worst
//! gap) as in-binary paired ratios that cancel the host's absolute speed.
mod common;
use common::{bench, BenchResult};
use dflop::fault::{FaultTrace, FleetState};
use dflop::model::catalog::{llama3, llava_ov};
use dflop::shard::ShardConfig;
use dflop::sim::{run_system, FaultConfig, RunConfig, RunResult, SystemKind};

/// The acceptance configuration shared with `tests/fleet.rs`: a 4-shard
/// fleet of single-node replicas on the skewed-shard dataset, long enough
/// for the scripted scenario (last heal at iteration 15) plus post-heal
/// iterations. Rebalancing stays on — since PR 10 the balancer prices
/// items by the confirmed per-shard slowdown, so it composes with the
/// fault-aware weighting.
fn fleet_cfg(trace: &str, respond: bool) -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 18, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: trace.to_string(), respond });
    cfg
}

/// A simulated-seconds row: the value is model output, not wall-clock,
/// so one rep with mean = min = max.
fn simulated(name: &str, v: f64) -> BenchResult {
    println!("{name:56} simulated {v:.6} s");
    BenchResult { name: name.to_string(), mean: v, min: v, max: v, reps: 1 }
}

fn main() {
    println!("== fault_bench ==");
    let mut results = Vec::new();

    // Per-boundary machinery: all µs-scale next to a pipeline sim.
    results.push(bench("generate long-horizon trace (512 iters, 8 shards)", 50, || {
        let t = FaultTrace::by_key("long-horizon", 8, 42).expect("trace");
        std::hint::black_box(t.events.len());
    }));
    let trace = FaultTrace::by_key("long-horizon", 8, 42).expect("trace");
    results.push(bench("replay 512 fleet boundaries (advance + counts)", 50, || {
        let mut fs = FleetState::new(trace.clone(), true, 2);
        let mut total = 0usize;
        for it in 0..512 {
            fs.advance(it);
            total += fs.counts(512).iter().sum::<usize>();
        }
        std::hint::black_box(total);
    }));

    // The acceptance pair: identical skewed-churn physics, the only
    // difference is whether the system responds.
    let m = llava_ov(llama3("8b"));
    let aware = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &fleet_cfg("skewed-churn", true));
    let stat = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &fleet_cfg("skewed-churn", false));
    let control = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &fleet_cfg("none", true));
    assert_eq!(control.replans, 0, "fault-free control replanned");
    let worst = |r: &RunResult| r.straggler_gaps.iter().cloned().fold(0.0f64, f64::max);
    results.push(simulated(
        "fleet mean step, fault-aware (skewed-churn, 4 shards)",
        aware.mean_iteration_time,
    ));
    results.push(simulated(
        "fleet mean step, static theta (skewed-churn, 4 shards)",
        stat.mean_iteration_time,
    ));
    results.push(simulated(
        "fleet worst straggler gap, fault-aware (skewed-churn, 4 shards)",
        worst(&aware),
    ));
    results.push(simulated(
        "fleet worst straggler gap, static theta (skewed-churn, 4 shards)",
        worst(&stat),
    ));

    common::emit_json("fault_bench", &results);
}
