//! Bench: 1F1B pipeline engine + full iteration simulation (supports the
//! end-to-end figures — one simulated iteration must stay in the ms range
//! so the figure sweeps complete in seconds).
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::optimizer::plan::{ModPar, Theta};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::pipeline::build::{iterate, SystemPlan};
use dflop::pipeline::sim::{simulate, Route};

fn main() {
    println!("== pipeline_bench ==");
    // Raw engine: 256 buckets × 16 stages.
    let routes: Vec<Route> = (0..256)
        .map(|i| Route {
            stages: (0..16).collect(),
            fwd: vec![1.0 + (i % 7) as f64 * 0.1; 16],
            bwd: vec![2.0; 16],
            comm: vec![0.0; 16],
        })
        .collect();
    bench("1F1B engine 256 buckets x 16 stages", 10, || {
        std::hint::black_box(simulate(16, &routes).makespan);
    });

    // Full iteration with ground-truth durations.
    let m = llava_ov(llama3("8b"));
    let truth = Truth::new(ClusterSpec::hgx_a100(4));
    let theta = Theta {
        enc: ModPar { tp: 1, pp: 1, dp: 4 },
        llm: ModPar { tp: 2, pp: 7, dp: 2 },
        n_mb: 16,
    };
    let plan = SystemPlan { m: &m, truth: &truth, theta };
    let mut ds = Dataset::mixed(1);
    let buckets: Vec<Vec<_>> = (0..theta.buckets())
        .map(|_| ds.shaped_batch(&m, 4))
        .collect();
    bench("full iteration (32 GPUs, 128 items)", 10, || {
        std::hint::black_box(iterate(&plan, &buckets).iteration_time);
    });
}
