//! Bench: 1F1B pipeline engine + full iteration simulation (supports the
//! end-to-end figures — one simulated iteration must stay in the ms range
//! so the figure sweeps complete in seconds).
//!
//! The engine rows measure the event-driven core the hot paths actually
//! run (reused `SimWorkspace`, no timeline) next to the retained polling
//! oracle (`simulate_reference`) — the in-binary before/after pair for the
//! PR-2 speedup claim (see `BENCH_PR2.json` / rust/DESIGN.md).
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::optimizer::plan::{ModPar, Theta};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::pipeline::build::{iterate_ws, SystemPlan};
use dflop::pipeline::sim::{simulate_reference, Route, SimWorkspace};

fn main() {
    println!("== pipeline_bench ==");
    let mut results = Vec::new();
    // Raw engine: 256 buckets × 16 stages.
    let routes: Vec<Route> = (0..256)
        .map(|i| Route {
            stages: (0..16).collect(),
            fwd: vec![1.0 + (i % 7) as f64 * 0.1; 16],
            bwd: vec![2.0; 16],
            comm: vec![0.0; 16],
        })
        .collect();
    let mut ws = SimWorkspace::new();
    results.push(bench("1F1B engine 256 buckets x 16 stages", 10, || {
        ws.routes.clear();
        for r in &routes {
            ws.routes.push_route(r);
        }
        std::hint::black_box(ws.run(16, false));
    }));
    results.push(bench("1F1B engine (timeline recorded)", 10, || {
        ws.routes.clear();
        for r in &routes {
            ws.routes.push_route(r);
        }
        std::hint::black_box(ws.run(16, true));
    }));
    results.push(bench("1F1B polling oracle (pre-PR2 baseline)", 10, || {
        std::hint::black_box(simulate_reference(16, &routes).makespan);
    }));

    // Delta re-simulation pair: the same 64-edit stream (one leg of one
    // bucket retimed per edit) costed as 64 full re-runs vs 64 delta
    // replays over a tracked workspace. These two rows back the PR-6 ≥3×
    // claim and are read by name in `dflop-bench-compare`; 64 edits per
    // repetition amortize timer noise in quick mode.
    let mut full_ws = SimWorkspace::new();
    full_ws.routes.clear();
    for r in &routes {
        full_ws.routes.push_route(r);
    }
    results.push(bench("full re-sim x64 single-bucket edits (256x16)", 10, || {
        for k in 0..64usize {
            let f = 1.0 + (k % 10) as f64 * 0.01;
            full_ws.update_leg(k * 37 % 256, k % 16, f, 2.0 + f * 0.5);
            std::hint::black_box(full_ws.run(16, false));
        }
    }));
    let mut delta_ws = SimWorkspace::new();
    delta_ws.routes.clear();
    for r in &routes {
        delta_ws.routes.push_route(r);
    }
    delta_ws.run_tracked(16);
    results.push(bench("delta re-sim x64 single-bucket edits (256x16)", 10, || {
        for k in 0..64usize {
            let f = 1.0 + (k % 10) as f64 * 0.01;
            delta_ws.update_leg(k * 37 % 256, k % 16, f, 2.0 + f * 0.5);
            std::hint::black_box(delta_ws.delta_run(16));
        }
    }));

    // Full iteration with ground-truth durations.
    let m = llava_ov(llama3("8b"));
    let truth = Truth::new(ClusterSpec::hgx_a100(4));
    let theta = Theta {
        enc: ModPar { tp: 1, pp: 1, dp: 4 },
        llm: ModPar { tp: 2, pp: 7, dp: 2 },
        n_mb: 16,
    };
    let plan = SystemPlan { m: &m, truth: &truth, theta };
    let mut ds = Dataset::mixed(1);
    let buckets: Vec<Vec<_>> = (0..theta.buckets())
        .map(|_| ds.shaped_batch(&m, 4))
        .collect();
    results.push(bench("full iteration (32 GPUs, 128 items)", 10, || {
        std::hint::black_box(iterate_ws(&plan, &buckets, &mut ws).iteration_time);
    }));
    common::emit_json("pipeline_bench", &results);
}
