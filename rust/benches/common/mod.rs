//! Minimal bench harness (criterion is not in the offline vendor set):
//! warm-up + repeated timing with mean/min/max reporting.
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Measured repetitions (reported in the printed line).
    #[allow(dead_code)]
    pub reps: usize,
}

pub fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> BenchResult {
    f(); // warm-up
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let r = BenchResult { name: name.to_string(), mean, min, max, reps };
    println!(
        "{:56} mean {:>10} min {:>10} max {:>10} ({} reps)",
        r.name,
        dflop::util::table::secs(r.mean),
        dflop::util::table::secs(r.min),
        dflop::util::table::secs(r.max),
        reps
    );
    r
}
