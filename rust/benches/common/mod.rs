//! Minimal bench harness (criterion is not in the offline vendor set):
//! warm-up + repeated timing with mean/min/max reporting.
//!
//! Setting `DFLOP_BENCH_QUICK=1` switches every target to smoke mode — a
//! single measured repetition (and, where a target honours it, a reduced
//! workload) — so CI can execute the full bench suite in seconds and fail
//! loudly on gross regressions without paying for stable statistics.
//!
//! Setting `DFLOP_BENCH_JSON=<path>` additionally records every result in
//! a machine-readable JSON document (see [`emit_json`]): the bench targets
//! run sequentially under `cargo bench` and each merges its rows into the
//! same file, which CI uploads as an artifact (`BENCH_PR10.json` since the
//! bubble-filling execution landed; the PR-2..9 protocol files read
//! identically).
//!
//! Setting `DFLOP_BENCH_JSON_DIR=<dir>` writes one *per-target* document
//! (`<dir>/BENCH_<target>.json`, same schema, only that target's rows) on
//! top of — or instead of — the merged file, so a CI run stays comparable
//! row-for-row against the single-target artifacts older PRs uploaded.
//! Both variables may be set at once.
use std::time::Instant;

/// True when the CI smoke mode is requested via `DFLOP_BENCH_QUICK`.
#[allow(dead_code)] // not every bench target reduces its workload
pub fn quick() -> bool {
    std::env::var("DFLOP_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

pub struct BenchResult {
    pub name: String,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Measured repetitions (reported in the printed line).
    #[allow(dead_code)]
    pub reps: usize,
}

pub fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> BenchResult {
    let reps = if quick() { 1 } else { reps };
    f(); // warm-up
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let r = BenchResult { name: name.to_string(), mean, min, max, reps };
    println!(
        "{:56} mean {:>10} min {:>10} max {:>10} ({} reps)",
        r.name,
        dflop::util::table::secs(r.mean),
        dflop::util::table::secs(r.min),
        dflop::util::table::secs(r.max),
        reps
    );
    r
}

/// Merge `results` into the JSON document named by `DFLOP_BENCH_JSON`
/// (no-op when the variable is unset). The document carries the thread
/// count and quick-mode flag alongside one row per (target, bench); rows
/// for a re-run (target, bench) pair are replaced, so the file stays
/// idempotent across repeated invocations.
pub fn emit_json(target: &str, results: &[BenchResult]) {
    use dflop::util::json::{emit, parse, Json};
    use std::collections::BTreeMap;

    let merged = std::env::var("DFLOP_BENCH_JSON").ok().filter(|p| !p.is_empty());
    let dir = std::env::var("DFLOP_BENCH_JSON_DIR").ok().filter(|p| !p.is_empty());
    if merged.is_none() && dir.is_none() {
        return;
    }

    let fresh_rows = || -> Vec<Json> {
        results
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("target".into(), Json::Str(target.into()));
                o.insert("bench".into(), Json::Str(r.name.clone()));
                o.insert("mean_s".into(), Json::Num(r.mean));
                o.insert("min_s".into(), Json::Num(r.min));
                o.insert("max_s".into(), Json::Num(r.max));
                o.insert("reps".into(), Json::Num(r.reps as f64));
                Json::Obj(o)
            })
            .collect()
    };
    let header = |root: &mut BTreeMap<String, Json>| {
        root.insert("schema".into(), Json::Str("dflop-bench-v1".into()));
        root.insert(
            "threads".into(),
            Json::Num(dflop::util::parallel::max_threads() as f64),
        );
        root.insert("quick".into(), Json::Bool(quick()));
    };

    if let Some(path) = merged {
        let mut root = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| parse(&text).ok())
            .and_then(|v| match v {
                Json::Obj(o) => Some(o),
                _ => None,
            })
            .unwrap_or_default();
        header(&mut root);
        let mut rows = match root.remove("results") {
            Some(Json::Arr(rows)) => rows,
            _ => Vec::new(),
        };
        // Drop this target's previous rows wholesale: a target always
        // reports its complete result set in one call, and keeping
        // partially-matching leftovers would mix rows from different
        // protocols under the one top-level threads/quick header.
        rows.retain(|row| {
            let Json::Obj(o) = row else { return false };
            o.get("target").and_then(Json::as_str) != Some(target)
        });
        rows.extend(fresh_rows());
        root.insert("results".into(), Json::Arr(rows));
        if let Err(e) = std::fs::write(&path, emit(&Json::Obj(root)) + "\n") {
            eprintln!("warning: could not write {path}: {e}");
        }
    }

    if let Some(dir) = dir {
        // Per-target document: always written fresh — one target, one
        // file, no merge step to go stale.
        let mut root = BTreeMap::new();
        header(&mut root);
        root.insert("results".into(), Json::Arr(fresh_rows()));
        let path = format!("{dir}/BENCH_{target}.json");
        if let Err(e) = std::fs::write(&path, emit(&Json::Obj(root)) + "\n") {
            eprintln!("warning: could not write {path}: {e}");
        }
    }
}
