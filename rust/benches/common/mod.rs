//! Minimal bench harness (criterion is not in the offline vendor set):
//! warm-up + repeated timing with mean/min/max reporting.
//!
//! Setting `DFLOP_BENCH_QUICK=1` switches every target to smoke mode — a
//! single measured repetition (and, where a target honours it, a reduced
//! workload) — so CI can execute the full bench suite in seconds and fail
//! loudly on gross regressions without paying for stable statistics.
use std::time::Instant;

/// True when the CI smoke mode is requested via `DFLOP_BENCH_QUICK`.
#[allow(dead_code)] // not every bench target reduces its workload
pub fn quick() -> bool {
    std::env::var("DFLOP_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

pub struct BenchResult {
    pub name: String,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Measured repetitions (reported in the printed line).
    #[allow(dead_code)]
    pub reps: usize,
}

pub fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) -> BenchResult {
    let reps = if quick() { 1 } else { reps };
    f(); // warm-up
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let r = BenchResult { name: name.to_string(), mean, min, max, reps };
    println!(
        "{:56} mean {:>10} min {:>10} max {:>10} ({} reps)",
        r.name,
        dflop::util::table::secs(r.mean),
        dflop::util::table::secs(r.min),
        dflop::util::table::secs(r.max),
        reps
    );
    r
}
