//! Bench: the observability subsystem — the zero-overhead-off guarantee
//! plus the wall-clock cost of the export paths.
//!
//! The gated pair is `fleet mean step, recorder off` vs `fleet mean
//! step, recorder on (timelines+metrics)`: both are *simulated* seconds
//! from the acceptance fleet run (skewed-churn, 4 shards), so the
//! recorder-on/recorder-off ratio is exactly 1.0 whenever the seam
//! holds its contract — the recorder copies values out and never feeds
//! anything back. The bench asserts bit-equality outright and
//! `dflop-bench-compare` gates the ratio at 1.02× so a protocol break
//! fails CI twice over. The real-time rows (trace/metrics export, bubble
//! extraction) are informational: one-shot end-of-run costs, not
//! per-iteration ones.
mod common;
use common::{bench, BenchResult};
use dflop::model::catalog::{llama3, llava_ov};
use dflop::obs::bubble::stage_bubbles;
use dflop::obs::chrome::{trace_json, validate_trace};
use dflop::obs::ObsConfig;
use dflop::shard::ShardConfig;
use dflop::sim::{run_system, FaultConfig, RunConfig, SystemKind};

/// The acceptance configuration shared with `tests/fleet.rs` and
/// `fault_bench`: a 4-shard fleet of single-node replicas replaying the
/// skewed-churn trace over skewed shard data.
fn fleet_cfg(obs: Option<ObsConfig>) -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 18, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: "skewed-churn".to_string(), respond: true });
    cfg.obs = obs;
    cfg
}

/// A simulated-seconds row: the value is model output, not wall-clock,
/// so one rep with mean = min = max.
fn simulated(name: &str, v: f64) -> BenchResult {
    println!("{name:56} simulated {v:.6} s");
    BenchResult { name: name.to_string(), mean: v, min: v, max: v, reps: 1 }
}

fn main() {
    println!("== obs_bench ==");
    let mut results = Vec::new();

    let m = llava_ov(llama3("8b"));
    let off = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &fleet_cfg(None));
    let on = run_system(
        SystemKind::DflopSharded,
        &m,
        "skewed-shard",
        &fleet_cfg(Some(ObsConfig { timelines: true, metrics: true, audit: false })),
    );
    // The contract behind the gate: observation changes nothing. A drift
    // here means the recorder fed a value back into the simulation.
    assert_eq!(
        off.mean_iteration_time.to_bits(),
        on.mean_iteration_time.to_bits(),
        "recorder-on changed the simulation: {} vs {}",
        off.mean_iteration_time,
        on.mean_iteration_time
    );
    assert_eq!(off.per_gpu_throughput.to_bits(), on.per_gpu_throughput.to_bits());
    results.push(simulated(
        "fleet mean step, recorder off (skewed-churn, 4 shards)",
        off.mean_iteration_time,
    ));
    results.push(simulated(
        "fleet mean step, recorder on (skewed-churn, 4 shards)",
        on.mean_iteration_time,
    ));

    // End-of-run export costs (wall-clock, informational).
    let log = on.obs.as_ref().expect("recorder was on");
    results.push(bench("chrome trace export (18-iter fleet log)", 20, || {
        std::hint::black_box(trace_json(log).len());
    }));
    let trace = trace_json(log);
    results.push(bench("chrome trace schema validation", 20, || {
        validate_trace(&trace).expect("valid trace");
    }));
    let reg = log.metrics.as_ref().expect("metrics were on");
    results.push(bench("metrics registry dump", 50, || {
        std::hint::black_box(reg.dump().len());
    }));
    results.push(bench("bubble extraction (all replica timelines)", 50, || {
        let mut gaps = 0usize;
        for it in &log.iterations {
            for rep in &it.replicas {
                gaps += stage_bubbles(
                    &rep.timeline,
                    rep.n_stages,
                    rep.makespan,
                    &rep.stage_busy,
                )
                .gaps
                .len();
            }
        }
        std::hint::black_box(gaps);
    }));

    common::emit_json("obs_bench", &results);
}
