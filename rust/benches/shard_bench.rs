//! Bench: the shard subsystem's per-step costs.
//!
//! The merge + skew gate run on *every* training step and must be
//! negligible next to a pipeline sim (µs); the bounded-migration
//! rebalance runs only while the gate reads skewed but still sits on the
//! step's critical path; the full sharded step (4 replicas fanned over
//! the pool) is the end-to-end unit the trainer repeats.
mod common;
use common::bench;
use dflop::data::item::ItemShape;
use dflop::model::catalog::{llama3, llava_ov};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{ModelProfiler, ProfilerGrids};
use dflop::profiling::estimator::Estimator;
use dflop::scheduler::lpt::ItemCost;
use dflop::shard::agg::{merge_shard_stats, ShardWindows};
use dflop::shard::balance::{rebalance, BalanceConfig};
use dflop::shard::partition::ShardedDataset;
use dflop::shard::sync::{cross_shard_allreduce, lpt_shard_buckets, simulate_shards, step_barrier};
use dflop::stream::window::ShapeStats;

fn main() {
    println!("== shard_bench ==");
    let mut results = Vec::new();
    let m = llava_ov(llama3("8b"));
    let shards = if common::quick() { 4 } else { 8 };

    // Per-step aggregation path: per-shard summaries → global merge →
    // skew gate.
    let mut sd = ShardedDataset::by_key("skewed-shard", shards, 7).expect("scenario");
    let counts = ShardedDataset::split_counts(512, shards);
    let batches = sd.shard_batches(&m, &counts);
    let per_stats: Vec<ShapeStats> =
        batches.iter().map(|b| ShapeStats::of_batch(b)).collect();
    results.push(bench(
        &format!("merge {shards} shard summaries (512 items total)"),
        50,
        || {
            std::hint::black_box(merge_shard_stats(&per_stats).items);
        },
    ));
    let mut sw = ShardWindows::new(shards, 6);
    for _ in 0..6 {
        sw.push(per_stats.clone());
    }
    results.push(bench("skew gate (per-shard drift stats vs pooled window)", 50, || {
        std::hint::black_box(sw.max_skew().expect("full").1.score());
    }));

    // Rebalance: 512 items, all homes deterministic, graded cost skew.
    let pooled: Vec<ItemShape> = batches.iter().flatten().copied().collect();
    let home: Vec<usize> = batches
        .iter()
        .enumerate()
        .flat_map(|(r, b)| std::iter::repeat(r).take(b.len()))
        .collect();
    let items: Vec<ItemCost> = pooled
        .iter()
        .map(|s| ItemCost {
            enc: s.units as f64 * 1e-3,
            llm: s.llm_seq as f64 * 1e-6,
        })
        .collect();
    results.push(bench(
        &format!("rebalance 512 items across {shards} shards"),
        20,
        || {
            let r = rebalance(&items, &home, shards, &BalanceConfig::default());
            std::hint::black_box(r.migrations);
        },
    ));

    // Larger instance with a free budget: the walk takes many more steps,
    // which is where the incrementally-sorted member lists (PR 6) pay off
    // over the per-step donor re-sort.
    let big_counts = ShardedDataset::split_counts(2048, shards);
    let big_batches = sd.shard_batches(&m, &big_counts);
    let big_items: Vec<ItemCost> = big_batches
        .iter()
        .flatten()
        .map(|s| ItemCost {
            enc: s.units as f64 * 1e-3,
            llm: s.llm_seq as f64 * 1e-6,
        })
        .collect();
    let big_home: Vec<usize> = big_batches
        .iter()
        .enumerate()
        .flat_map(|(r, b)| std::iter::repeat(r).take(b.len()))
        .collect();
    let free = BalanceConfig { migration_budget: 1.0, min_gain: 0.0 };
    results.push(bench(
        &format!("rebalance 2048 items across {shards} shards (free budget)"),
        10,
        || {
            let r = rebalance(&big_items, &big_home, shards, &free);
            std::hint::black_box(r.migrations);
        },
    ));

    // Full sharded step: per-replica LPT + 1F1B fan-out + barrier.
    let cluster = ClusterSpec::hgx_a100(1);
    let truth = Truth::new(cluster);
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let est = Estimator::new(&m, &profile.throughput);
    let theta = dflop::optimizer::plan::Theta {
        enc: dflop::optimizer::plan::ModPar { tp: 1, pp: 1, dp: 1 },
        llm: dflop::optimizer::plan::ModPar { tp: 1, pp: 7, dp: 1 },
        n_mb: 8,
    };
    let step_counts = ShardedDataset::split_counts(128, shards);
    let step_batches = sd.shard_batches(&m, &step_counts);
    results.push(bench(
        &format!("sharded step: {shards} replicas, 128 items (LPT + sim + barrier)"),
        10,
        || {
            let buckets: Vec<Vec<Vec<ItemShape>>> = step_batches
                .iter()
                .map(|b| lpt_shard_buckets(&est, theta, b))
                .collect();
            let per = simulate_shards(&m, &truth, theta, &buckets);
            let barrier = step_barrier(
                per.iter().map(|s| s.iteration_time).collect(),
                cross_shard_allreduce(&m, &truth, theta, shards),
            );
            std::hint::black_box(barrier.step_time);
        },
    ));

    common::emit_json("shard_bench", &results);
}
