//! Bench: Online Microbatch Scheduler (paper Fig 16b).
//!
//! ILP with a strict 50 ms limit; LPT fallback at large GBS; imbalance vs
//! the perfect-balance lower bound stays ≈1% (the paper's claim).
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::scheduler::ilp;
use dflop::scheduler::lpt::{self, ItemCost};
use std::time::Duration;

fn main() {
    let m = llava_ov(llama3("8b"));
    let mut ds = Dataset::mixed(42);
    println!("== scheduler_bench (Fig 16b) ==");
    let mut results = Vec::new();
    for &gbs in &[64usize, 256, 1024, 2048] {
        let shapes = ds.shaped_batch(&m, gbs);
        let items: Vec<ItemCost> = shapes
            .iter()
            .map(|s| ItemCost { enc: s.units as f64, llm: s.llm_seq as f64 })
            .collect();
        let buckets = (gbs / 8).max(2);
        let lb = lpt::lower_bound(&items, buckets);
        let mut imb = 0.0;
        results.push(bench(&format!("hybrid ILP/LPT gbs={gbs} m={buckets}"), 5, || {
            let r = ilp::solve(&items, buckets, Duration::from_millis(50));
            imb = (r.assignment.c_max() / lb - 1.0).max(0.0);
        }));
        println!("    imbalance vs lower bound: {:.3}%", imb * 100.0);
        // Reused-output LPT — the exact call shape of the optimizer's
        // Eq-1 refinement inner loop.
        let mut out = dflop::scheduler::lpt::Assignment::default();
        results.push(bench(&format!("LPT only gbs={gbs} m={buckets}"), 5, || {
            lpt::lpt_into(&items, buckets, &mut out);
            std::hint::black_box(out.c_max());
        }));
    }
    common::emit_json("scheduler_bench", &results);
}
