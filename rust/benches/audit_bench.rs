//! Bench: the counterfactual pricer behind `obs::audit`.
//!
//! The gated pair is `cf pricing x64 batches, delta replay` vs
//! `cf pricing x64 batches, fresh re-sim`: the same 64 realized batches
//! priced under the same incumbent θ, once through the standing route
//! set (`update_leg` + `delta_run`, the path `run_audit` takes) and once
//! rebuilding the full route set and running a fresh tracked simulation
//! per batch (the oracle). The bench asserts bit-equality of every
//! priced makespan outright — the audit's correctness contract — and
//! `dflop-bench-compare` gates delta replay at ≤ ½× the fresh cost, the
//! reason the audit can afford to re-price every epoch's batches.
mod common;
use common::{bench, emit_json};
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llama3, llava_ov};
use dflop::obs::audit::CfPricer;
use dflop::optimizer::plan::{ModPar, Theta};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{ModelProfiler, ProfilerGrids};

fn main() {
    println!("== audit_bench ==");
    let mut results = Vec::new();

    let m = llava_ov(llama3("8b"));
    let mut backend = SimBackend::new(Truth::new(ClusterSpec::hgx_a100(1)));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let theta = Theta {
        enc: ModPar { tp: 1, pp: 1, dp: 2 },
        llm: ModPar { tp: 2, pp: 2, dp: 1 },
        n_mb: 8,
    };
    // 64 realized batches at constant GBS: the steady-state shape the
    // audit re-prices (bucket count never changes, so delta replay stays
    // on the standing routes after batch 0). The workload is cheap
    // enough to keep constant in quick mode — `bench` already drops to
    // one rep — so the row names the compare gate matches never change.
    let n_batches = 64;
    let gbs = 64;
    let mut ds = Dataset::mixed(42);
    let batches: Vec<Vec<_>> = (0..n_batches).map(|_| ds.shaped_batch(&m, gbs)).collect();

    // Correctness first: the two paths must agree to the bit on every
    // batch, or the benched speedup is pricing something else.
    let mut delta = CfPricer::new(&m, &profile.throughput, theta);
    let mut fresh = CfPricer::new(&m, &profile.throughput, theta);
    for (i, b) in batches.iter().enumerate() {
        let d = delta.price(b);
        let f = fresh.price_fresh(b);
        assert_eq!(
            d.to_bits(),
            f.to_bits(),
            "delta replay diverged from fresh re-sim on batch {i}: {d} vs {f}"
        );
    }

    results.push(bench(
        &format!("cf pricing x{n_batches} batches, delta replay (gbs {gbs})"),
        20,
        || {
            let mut p = CfPricer::new(&m, &profile.throughput, theta);
            let mut acc = 0.0f64;
            for b in &batches {
                acc += p.price(b);
            }
            std::hint::black_box(acc);
        },
    ));
    results.push(bench(
        &format!("cf pricing x{n_batches} batches, fresh re-sim (gbs {gbs})"),
        20,
        || {
            let mut p = CfPricer::new(&m, &profile.throughput, theta);
            let mut acc = 0.0f64;
            for b in &batches {
                acc += p.price_fresh(b);
            }
            std::hint::black_box(acc);
        },
    ));

    emit_json("audit_bench", &results);
}
