//! Golden parity suite for the PR-5 engine extraction.
//!
//! `reference_run_system` / `reference_run_sharded` below are verbatim
//! transcriptions of the two training loops that lived in
//! `sim::trainer` before the `engine` layer replaced them (the
//! PR-2-style oracle pattern: keep the old implementation as the
//! bit-exactness reference). The one deliberate delta is carried on both
//! sides: this PR's drift-aware Adaptive Correction satellite resets the
//! Eq-7 penalties at a plan swap, so the reference performs the same
//! reset — everything else is the pre-refactor code, line for line.
//!
//! Every `SystemKind` must produce bit-identical telemetry through
//! `engine::run` vs the reference, at `--threads 1` and `--threads 8`.
//! Wall-clock fields (`sched_elapsed` durations, `profiling_seconds`,
//! `optimizer_elapsed`) are compared by shape only — they are real timer
//! reads on both sides. The scheduled systems run with a 10 s ILP budget
//! over small batches so every branch-and-bound call proves optimality:
//! a budget-expired incumbent is wall-clock-dependent by design
//! (`scheduler::ilp`) and would make *any* run-to-run comparison
//! meaningless; the suite asserts `lpt_fallbacks == 0` so a too-hard
//! instance fails loudly instead of flaking.

use dflop::baselines::homogeneous::{
    megatron_tune, pytorch_tune, random_buckets, PYTORCH_SOFTWARE_FACTOR,
};
use dflop::data::dataset::Dataset;
use dflop::data::item::ItemShape;
use dflop::fault::FaultStats;
use dflop::model::catalog::{internvl_25, llama3, llava_ov, qwen25, Mllm};
use dflop::optimizer::plan::Theta;
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::pipeline::build::{iterate_ws, IterationStats};
use dflop::pipeline::sim::SimWorkspace;
use dflop::profiling::backend::{MeasureBackend, SimBackend};
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use dflop::profiling::estimator::Estimator;
use dflop::scheduler::correction::{Correction, CorrectionConfig};
use dflop::scheduler::lpt::ItemCost;
use dflop::scheduler::online::{OnlineScheduler, SchedulerConfig, Solver};
use dflop::shard::agg::{merge_shard_stats, ShardWindows};
use dflop::shard::balance::rebalance;
use dflop::shard::partition::ShardedDataset;
use dflop::shard::sync::{
    cross_shard_allreduce, lpt_shard_buckets, simulate_shards, step_barrier, BarrierStats,
};
use dflop::shard::ShardConfig;
use dflop::sim::{RunConfig, RunResult, SystemKind};
use dflop::stream::replan::{ReplanConfig, ReplanContext, Replanner};
use dflop::stream::window::ShapeStats;
use dflop::util::parallel::set_max_threads;
use dflop::util::rng::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// The pool width is process-global; tests that flip it hold this lock.
static WIDTH_LOCK: Mutex<()> = Mutex::new(());

fn width_guard() -> std::sync::MutexGuard<'static, ()> {
    WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------------------------
// The pre-refactor loops, transcribed.
// ------------------------------------------------------------------

fn materialize(shapes: &[ItemShape], groups: &[Vec<usize>]) -> Vec<Vec<ItemShape>> {
    groups
        .iter()
        .map(|g| g.iter().map(|&i| shapes[i]).collect())
        .collect()
}

/// Pre-engine `run_system` (non-sharded kinds).
fn reference_run_system(
    kind: SystemKind,
    m: &Mllm,
    dataset_key: &str,
    cfg: &RunConfig,
) -> RunResult {
    assert_ne!(kind, SystemKind::DflopSharded, "use reference_run_sharded");
    let cluster = ClusterSpec::hgx_a100(cfg.nodes);
    let mut truth = Truth::new(cluster);
    truth.injected = cfg.injected.clone();
    if kind == SystemKind::Pytorch {
        truth.software_factor = PYTORCH_SOFTWARE_FACTOR;
    }

    // ---- offline phase ----
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(cluster.gpus_per_node))
        .profile(m);
    let mut profile_ds = Dataset::by_key(dataset_key, cfg.seed ^ 0xDA7A)
        .unwrap_or_else(|| panic!("unknown dataset '{dataset_key}'"));
    let data = profile_data(m, &mut profile_ds, cfg.profile_samples);
    let profiling_seconds = backend.measured_seconds().max(data.profiling_seconds);

    let (mut theta, optimizer_elapsed) = match kind {
        SystemKind::Dflop
        | SystemKind::DflopInterleaved
        | SystemKind::DflopAdaptive
        | SystemKind::DflopOptimizerOnly => {
            let inp = OptimizerInputs {
                m,
                profile: &profile,
                data: &data,
                n_gpus: cluster.total_gpus(),
                gpus_per_node: cluster.gpus_per_node,
                mem_capacity: cluster.gpu.mem_bytes,
                gbs: cfg.gbs,
                assume_balanced: kind != SystemKind::DflopOptimizerOnly,
            };
            let r = optimize(&inp).expect("no feasible DFLOP configuration");
            (r.theta, r.elapsed)
        }
        SystemKind::DflopSchedulerOnly | SystemKind::Megatron => {
            let c = megatron_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible Megatron configuration");
            (c.theta, Duration::ZERO)
        }
        SystemKind::Pytorch => {
            let c = pytorch_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible PyTorch configuration");
            (c.theta, Duration::ZERO)
        }
        SystemKind::DflopSharded => unreachable!(),
    };

    // ---- online phase ----
    let est = Estimator::new(m, &profile.throughput);
    let uses_scheduler = matches!(
        kind,
        SystemKind::Dflop
            | SystemKind::DflopInterleaved
            | SystemKind::DflopAdaptive
            | SystemKind::DflopSchedulerOnly
    );
    let mut correction_cfg = CorrectionConfig::default();
    if cfg.disable_correction {
        correction_cfg.window = 1;
        correction_cfg.cost_fraction = f64::INFINITY;
    }
    let mut scheduler = OnlineScheduler::new(
        theta,
        SchedulerConfig { ilp_budget: cfg.ilp_budget },
        Correction::new(correction_cfg),
    );

    let mut ds = Dataset::by_key(dataset_key, cfg.seed).expect("dataset");
    let mut rng = Rng::new(cfg.seed ^ 0xB0CC);

    let mut replanner = if kind == SystemKind::DflopAdaptive {
        Some(Replanner::new(
            &data,
            theta,
            cfg.replan.clone().unwrap_or_default(),
        ))
    } else {
        None
    };
    let rctx = ReplanContext {
        m,
        profile: &profile,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: cfg.gbs,
    };

    let mut sim_ws = SimWorkspace::new();
    let mut iterations = Vec::with_capacity(cfg.iters);
    let mut sched_elapsed = Vec::with_capacity(cfg.iters);
    let mut lpt_fallbacks = 0usize;
    let mut stage_thr_samples = Vec::new();
    let mut bucket_enc_times = Vec::new();
    let mut bucket_llm_times = Vec::new();

    for _ in 0..cfg.iters {
        let shapes = ds.shaped_batch(m, cfg.gbs);

        if let Some(rp) = replanner.as_mut() {
            if let Some(new_theta) = rp.observe_batch(&rctx, &shapes) {
                theta = new_theta;
                scheduler.theta = new_theta;
                // PR-5 satellite, mirrored on both sides: stale Eq-7
                // penalties reset with the plan.
                scheduler.correction.reset_penalties();
            }
        }
        let plan = dflop::pipeline::build::SystemPlan { m, truth: &truth, theta };

        let buckets: Vec<Vec<ItemShape>> = if uses_scheduler {
            let sched = scheduler.schedule(&est, &shapes);
            sched_elapsed.push(sched.elapsed);
            if sched.solver == Solver::LptFallback {
                lpt_fallbacks += 1;
            }
            materialize(&shapes, &sched.assignment.buckets)
        } else {
            let t0 = std::time::Instant::now();
            let b = random_buckets(&shapes, theta.buckets(), &mut rng);
            sched_elapsed.push(t0.elapsed());
            b
        };

        let stats = iterate_ws(&plan, &buckets, &mut sim_ws);

        // ---- Adaptive Correction feedback (Eq 7) ----
        if uses_scheduler && scheduler.correction.is_active() {
            let mut observations = Vec::new();
            let mut mispredicted = 0.0;
            let l_layers = m.llm.layers as f64;
            for bucket in &buckets {
                let total: f64 = bucket.iter().map(|i| i.llm_seq as f64).sum();
                if total <= 0.0 {
                    continue;
                }
                for item in bucket {
                    let seq = item.llm_seq as f64;
                    if seq <= 0.0 {
                        continue;
                    }
                    let lin_share = truth
                        .llm_linear_time(m, total, l_layers, theta.llm.tp)
                        * seq
                        / total;
                    let attn = truth.llm_attn_time(m, seq, l_layers, theta.llm.tp);
                    let actual = lin_share + attn;
                    let pred = est.llm_item_dur(item, theta.llm.tp);
                    let flop = item.llm_flop(m);
                    observations.push((
                        Truth::llm_bucket(seq),
                        flop / actual,
                        flop / pred,
                    ));
                    mispredicted += (actual - pred).abs() / theta.llm.pp as f64;
                }
            }
            let benefit = mispredicted
                / (stats.buckets.len().max(1) as f64)
                / stats.pipeline_makespan.max(1e-12);
            scheduler.feedback(&observations, benefit);
        }

        stage_thr_samples.extend(stats.stage_throughputs());
        for b in &stats.buckets {
            if b.enc_time > 0.0 {
                bucket_enc_times.push(b.enc_time);
            }
            if b.llm_time > 0.0 {
                bucket_llm_times.push(b.llm_time);
            }
        }
        iterations.push(stats);
    }

    let n = iterations.len().max(1) as f64;
    let mean_iter = iterations.iter().map(|s| s.iteration_time).sum::<f64>() / n;
    let mean_idle = iterations.iter().map(|s| s.total_idle()).sum::<f64>() / n;
    let mean_thr = iterations
        .iter()
        .map(|s| s.cluster_throughput())
        .sum::<f64>()
        / n;

    let (replans, replan_events) = match replanner {
        Some(rp) => (rp.swaps(), rp.events),
        None => (0, Vec::new()),
    };

    RunResult {
        system: kind,
        theta,
        n_gpus: cluster.total_gpus(),
        per_gpu_throughput: mean_thr / cluster.total_gpus() as f64,
        mean_iteration_time: mean_iter,
        mean_idle,
        stage_throughput_samples: stage_thr_samples,
        bucket_enc_times,
        bucket_llm_times,
        sched_elapsed,
        lpt_fallbacks,
        profiling_seconds,
        optimizer_elapsed,
        replans,
        replan_events,
        straggler_gaps: Vec::new(),
        straggler_gap_percentiles: Vec::new(),
        migrations: 0,
        fault: FaultStats::default(),
        hetero_thetas: Vec::new(),
        iterations,
        obs: None,
    }
}

fn merge_shard_iterations(per: Vec<IterationStats>, barrier: &BarrierStats) -> IterationStats {
    let pipeline_max = per.iter().map(|s| s.pipeline_makespan).fold(0.0, f64::max);
    let n_stages = per.iter().map(|s| s.n_stages).sum();
    let mut stage_busy = Vec::with_capacity(n_stages);
    let mut stage_flop = Vec::with_capacity(n_stages);
    let mut buckets = Vec::new();
    let mut total_flop = 0.0;
    for s in per {
        stage_busy.extend(s.stage_busy);
        stage_flop.extend(s.stage_flop);
        buckets.extend(s.buckets);
        total_flop += s.total_flop;
    }
    let stage_idle = stage_busy.iter().map(|&b| pipeline_max - b).collect();
    IterationStats {
        iteration_time: barrier.step_time,
        pipeline_makespan: pipeline_max,
        dp_sync_time: barrier.step_time - pipeline_max,
        stage_busy,
        stage_idle,
        stage_flop,
        n_stages,
        total_flop,
        buckets,
        timeline: Vec::new(),
        fills: Vec::new(),
    }
}

/// Pre-engine `run_sharded`.
fn reference_run_sharded(m: &Mllm, scenario: &str, cfg: &RunConfig) -> RunResult {
    let sc = cfg.shard.clone().unwrap_or_default();
    let shards = sc.dp_shards;
    assert!(shards >= 1, "sharded run needs at least one shard");
    assert!(cfg.gbs >= shards, "per-shard batch must be non-empty");
    let cluster = ClusterSpec::hgx_a100(cfg.nodes);
    let mut truth = Truth::new(cluster);
    truth.injected = cfg.injected.clone();

    // ---- offline phase: model profile + pooled data profile + θ* ----
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(cluster.gpus_per_node))
        .profile(m);
    let mut profile_sd = ShardedDataset::by_key(scenario, shards, cfg.seed ^ 0xDA7A)
        .unwrap_or_else(|| panic!("unknown shard scenario '{scenario}'"));
    let data = profile_sd.profile_pooled(m, cfg.profile_samples);
    let profiling_seconds = backend.measured_seconds().max(data.profiling_seconds);

    let rctx = ReplanContext {
        m,
        profile: &profile,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: cfg.gbs.div_ceil(shards),
    };
    let r0 = optimize(&rctx.inputs(&data)).expect("no feasible sharded configuration");
    let (mut theta, optimizer_elapsed) = (r0.theta, r0.elapsed);

    // ---- online phase ----
    let est = Estimator::new(m, &profile.throughput);
    let mut sd = ShardedDataset::by_key(scenario, shards, cfg.seed).expect("scenario");
    let counts = ShardedDataset::split_counts(cfg.gbs, shards);
    let mut replanner =
        Replanner::new(&data, theta, cfg.replan.clone().unwrap_or_default());
    let mut gate = ShardWindows::new(shards, sc.window_batches);

    let mut iterations = Vec::with_capacity(cfg.iters);
    let mut sched_elapsed = Vec::with_capacity(cfg.iters);
    let mut straggler_gaps = Vec::with_capacity(cfg.iters);
    let mut migrations = 0usize;
    let mut stage_thr_samples = Vec::new();
    let mut bucket_enc_times = Vec::new();
    let mut bucket_llm_times = Vec::new();

    for _ in 0..cfg.iters {
        let shard_batches = sd.shard_batches(m, &counts);

        let per_stats: Vec<ShapeStats> =
            shard_batches.iter().map(|b| ShapeStats::of_batch(b)).collect();
        let merged = merge_shard_stats(&per_stats);
        let pooled: Vec<ItemShape> =
            shard_batches.iter().flat_map(|b| b.iter().copied()).collect();
        if let Some(new_theta) = replanner.observe_stats(&rctx, merged, &pooled) {
            theta = new_theta;
        }
        gate.push(per_stats);

        let t0 = std::time::Instant::now();
        let home: Vec<usize> = shard_batches
            .iter()
            .enumerate()
            .flat_map(|(r, b)| std::iter::repeat(r).take(b.len()))
            .collect();
        let groups: Vec<Vec<usize>> = if sc.rebalance && gate.skewed(sc.skew_enter) {
            let items: Vec<ItemCost> = pooled
                .iter()
                .map(|s| ItemCost {
                    enc: est.enc_item_dur(s, theta.enc.tp) / theta.enc.pp as f64,
                    llm: est.llm_item_dur(s, theta.llm.tp) / theta.llm.pp as f64,
                })
                .collect();
            let rb = rebalance(&items, &home, shards, &sc.balance);
            migrations += rb.migrations;
            rb.groups(shards)
        } else {
            let mut g: Vec<Vec<usize>> = vec![Vec::new(); shards];
            for (i, &r) in home.iter().enumerate() {
                g[r].push(i);
            }
            g
        };

        let shard_buckets: Vec<Vec<Vec<ItemShape>>> = groups
            .iter()
            .map(|g| {
                let shapes: Vec<ItemShape> = g.iter().map(|&i| pooled[i]).collect();
                lpt_shard_buckets(&est, theta, &shapes)
            })
            .collect();
        sched_elapsed.push(t0.elapsed());

        let per_replica = simulate_shards(m, &truth, theta, &shard_buckets);
        let barrier = step_barrier(
            per_replica.iter().map(|s| s.iteration_time).collect(),
            cross_shard_allreduce(m, &truth, theta, shards),
        );
        straggler_gaps.push(barrier.straggler_gap);
        let stats = merge_shard_iterations(per_replica, &barrier);

        stage_thr_samples.extend(stats.stage_throughputs());
        for b in &stats.buckets {
            if b.enc_time > 0.0 {
                bucket_enc_times.push(b.enc_time);
            }
            if b.llm_time > 0.0 {
                bucket_llm_times.push(b.llm_time);
            }
        }
        iterations.push(stats);
    }

    let n = iterations.len().max(1) as f64;
    let mean_iter = iterations.iter().map(|s| s.iteration_time).sum::<f64>() / n;
    let mean_idle = iterations.iter().map(|s| s.total_idle()).sum::<f64>() / n;
    let mean_thr = iterations
        .iter()
        .map(|s| s.cluster_throughput())
        .sum::<f64>()
        / n;
    let n_gpus = cluster.total_gpus() * shards;

    RunResult {
        system: SystemKind::DflopSharded,
        theta,
        n_gpus,
        per_gpu_throughput: mean_thr / n_gpus as f64,
        mean_iteration_time: mean_iter,
        mean_idle,
        stage_throughput_samples: stage_thr_samples,
        bucket_enc_times,
        bucket_llm_times,
        sched_elapsed,
        lpt_fallbacks: 0,
        profiling_seconds,
        optimizer_elapsed,
        replans: replanner.swaps(),
        replan_events: replanner.events,
        straggler_gaps,
        straggler_gap_percentiles: Vec::new(),
        migrations,
        fault: FaultStats::default(),
        hetero_thetas: Vec::new(),
        iterations,
        obs: None,
    }
}

// ------------------------------------------------------------------
// The comparison.
// ------------------------------------------------------------------

fn assert_bits(a: f64, b: f64, what: &str, label: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{label}: {what} drifted ({a} vs {b})");
}

/// Bitwise telemetry parity (wall-clock fields by shape only).
fn assert_parity(reference: &RunResult, engine: &RunResult, label: &str) {
    assert_eq!(reference.system, engine.system, "{label}: system");
    assert_eq!(reference.theta, engine.theta, "{label}: final θ");
    assert_eq!(reference.n_gpus, engine.n_gpus, "{label}: n_gpus");
    assert_bits(
        reference.per_gpu_throughput,
        engine.per_gpu_throughput,
        "per-GPU throughput",
        label,
    );
    assert_bits(
        reference.mean_iteration_time,
        engine.mean_iteration_time,
        "mean iteration time",
        label,
    );
    assert_bits(reference.mean_idle, engine.mean_idle, "mean idle", label);
    assert_eq!(
        reference.stage_throughput_samples.len(),
        engine.stage_throughput_samples.len(),
        "{label}: stage sample count"
    );
    for (i, (a, b)) in reference
        .stage_throughput_samples
        .iter()
        .zip(&engine.stage_throughput_samples)
        .enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: stage sample {i}");
    }
    assert_eq!(reference.bucket_enc_times.len(), engine.bucket_enc_times.len());
    assert_eq!(reference.bucket_llm_times.len(), engine.bucket_llm_times.len());
    for (a, b) in reference.bucket_llm_times.iter().zip(&engine.bucket_llm_times) {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: bucket LLM time");
    }
    assert_eq!(reference.sched_elapsed.len(), engine.sched_elapsed.len());
    assert_eq!(reference.lpt_fallbacks, engine.lpt_fallbacks, "{label}: fallbacks");
    assert!(reference.profiling_seconds > 0.0 && engine.profiling_seconds > 0.0);
    assert_eq!(reference.replans, engine.replans, "{label}: replans");
    type EventKey = (usize, Theta, Theta, bool, u64);
    let events = |r: &RunResult| -> Vec<EventKey> {
        r.replan_events
            .iter()
            .map(|e| (e.iteration, e.old, e.new, e.swapped, e.expected_makespan.to_bits()))
            .collect()
    };
    assert_eq!(events(reference), events(engine), "{label}: replan events");
    assert_eq!(
        reference.straggler_gaps.len(),
        engine.straggler_gaps.len(),
        "{label}: gap count"
    );
    for (i, (a, b)) in reference
        .straggler_gaps
        .iter()
        .zip(&engine.straggler_gaps)
        .enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: straggler gap {i}");
    }
    assert_eq!(reference.migrations, engine.migrations, "{label}: migrations");
    assert_eq!(reference.hetero_thetas, engine.hetero_thetas, "{label}: hetero plans");
    assert_eq!(reference.iterations.len(), engine.iterations.len());
    for (i, (a, b)) in reference.iterations.iter().zip(&engine.iterations).enumerate() {
        assert_eq!(
            a.iteration_time.to_bits(),
            b.iteration_time.to_bits(),
            "{label}: iteration {i} time"
        );
        assert_eq!(
            a.total_flop.to_bits(),
            b.total_flop.to_bits(),
            "{label}: iteration {i} FLOP"
        );
        assert_eq!(a.n_stages, b.n_stages, "{label}: iteration {i} stages");
    }
}

fn check_kind_at_widths(kind: SystemKind, m: &Mllm, dataset: &str, cfg: &RunConfig) {
    for threads in [1usize, 8] {
        set_max_threads(threads);
        let reference = if kind == SystemKind::DflopSharded {
            reference_run_sharded(m, dataset, cfg)
        } else {
            reference_run_system(kind, m, dataset, cfg)
        };
        let engine = dflop::engine::run(kind, m, dataset, cfg).expect("valid run");
        assert_parity(
            &reference,
            &engine,
            &format!("{kind:?}/{dataset}@threads={threads}"),
        );
    }
    set_max_threads(0);
}

#[test]
fn parity_budget_free_kinds() {
    let _g = width_guard();
    // Megatron / PyTorch / optimizer-only never touch the deadline ILP,
    // so full bitwise parity holds unconditionally.
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 32, 3, 42);
    cfg.profile_samples = 256;
    for kind in [
        SystemKind::Megatron,
        SystemKind::Pytorch,
        SystemKind::DflopOptimizerOnly,
    ] {
        check_kind_at_widths(kind, &m, "mixed", &cfg);
    }
}

#[test]
fn parity_scheduled_kinds() {
    let _g = width_guard();
    // The ILP-scheduled systems: small batches + a 10 s budget keep every
    // branch-and-bound call provably optimal, hence deterministic.
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 16, 3, 42);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);
    for kind in [SystemKind::Dflop, SystemKind::DflopSchedulerOnly] {
        check_kind_at_widths(kind, &m, "mixed", &cfg);
        // The comparison is only meaningful when the ILP proved
        // optimality throughout (see module docs).
        let r = dflop::engine::run(kind, &m, "mixed", &cfg).expect("valid run");
        assert_eq!(
            r.lpt_fallbacks, 0,
            "{kind:?}: ILP budget expired — shrink the parity instance"
        );
    }
}

#[test]
fn parity_adaptive_on_curriculum() {
    let _g = width_guard();
    // The replanner-in-the-loop path: drift windows, warm restarts, plan
    // swaps, and the correction reset all run on both sides.
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 16, 12, 42);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);
    let mut rp = ReplanConfig { window_batches: 4, cooldown: 4, ..ReplanConfig::default() };
    rp.drift.confirm = 1;
    cfg.replan = Some(rp);
    check_kind_at_widths(SystemKind::DflopAdaptive, &m, "curriculum", &cfg);
    let r = dflop::engine::run(SystemKind::DflopAdaptive, &m, "curriculum", &cfg)
        .expect("valid run");
    assert_eq!(r.lpt_fallbacks, 0, "ILP budget expired — shrink the parity instance");
}

#[test]
fn parity_interleaved_with_fill_disabled_is_plain_dflop() {
    let _g = width_guard();
    // PR-10 anchor: with `bubble_fill = false` the interleaved system
    // must run the exact plain-DFLOP execution path — the reference
    // transcription (which has no fill pass at all) is the oracle, at
    // both pool widths. Same provably-optimal ILP regime as
    // `parity_scheduled_kinds`.
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 16, 3, 42);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);
    cfg.bubble_fill = false;
    check_kind_at_widths(SystemKind::DflopInterleaved, &m, "mixed", &cfg);
    let r = dflop::engine::run(SystemKind::DflopInterleaved, &m, "mixed", &cfg)
        .expect("valid run");
    assert_eq!(r.lpt_fallbacks, 0, "ILP budget expired — shrink the parity instance");
    assert!(r.iterations.iter().all(|s| s.fills.is_empty()), "fill ran while disabled");
}

#[test]
fn interleaved_fill_is_bit_deterministic_across_thread_counts() {
    let _g = width_guard();
    // The fill pass itself (measure → shrink → pack on the re-simulated
    // timeline) is serial f64 arithmetic, so an interleaved run must be
    // bit-identical at any pool width — telemetry, traces, and metrics
    // included. The video mixture on InternVL makes the pass actually
    // place sub-ops, so this pins the live path, not a no-op.
    let m = internvl_25(qwen25("7b"));
    let mut cfg = RunConfig::new(2, 16, 3, 42);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);
    cfg.obs = Some(dflop::obs::ObsConfig { timelines: true, metrics: true, audit: false });
    set_max_threads(1);
    let serial = dflop::engine::run(SystemKind::DflopInterleaved, &m, "video", &cfg)
        .expect("valid run");
    set_max_threads(8);
    let parallel = dflop::engine::run(SystemKind::DflopInterleaved, &m, "video", &cfg)
        .expect("valid run");
    set_max_threads(0);
    assert_eq!(serial.lpt_fallbacks, 0, "ILP budget expired — shrink the instance");
    assert!(
        serial.iterations.iter().any(|s| !s.fills.is_empty()),
        "fill pass never placed a sub-op — the determinism check is vacuous"
    );
    assert_parity(&serial, &parallel, "DflopInterleaved/video@threads=1-vs-8");
    // The fill ledger bit-matches op for op.
    for (i, (a, b)) in serial.iterations.iter().zip(&parallel.iterations).enumerate() {
        assert_eq!(a.fills.len(), b.fills.len(), "iteration {i}: fill count");
        for (x, y) in a.fills.iter().zip(&b.fills) {
            assert_eq!(x, y, "iteration {i}: fill op drifted");
        }
    }
    // Traces and metrics are part of the contract too.
    let sl = serial.obs.as_ref().expect("obs log");
    let pl = parallel.obs.as_ref().expect("obs log");
    assert_eq!(
        dflop::obs::chrome::trace_json(sl),
        dflop::obs::chrome::trace_json(pl),
        "Chrome trace drifted with thread count"
    );
    assert_eq!(
        sl.metrics.as_ref().expect("metrics").dump(),
        pl.metrics.as_ref().expect("metrics").dump(),
        "metrics dump drifted with thread count"
    );
}

#[test]
fn parity_sharded_kinds() {
    let _g = width_guard();
    // The sharded path is budget-free end to end; skewed-shard exercises
    // the gate + migration walk, curriculum the global replan.
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 48, 10, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    check_kind_at_widths(SystemKind::DflopSharded, &m, "skewed-shard", &cfg);
    let mut curr = cfg.clone();
    curr.iters = 12;
    check_kind_at_widths(SystemKind::DflopSharded, &m, "curriculum", &curr);
}
