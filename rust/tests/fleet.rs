//! Integration tests for the fault-injected elastic fleet (PR 7).
//!
//! The contract under test: fault delivery happens at iteration
//! boundaries from a seeded, replayable `FaultTrace`, so a fleet run is
//! bit-identical at any `DFLOP_THREADS`; a `"none"` trace leaves the
//! healthy pipeline bit-untouched; resharding round-trips; and on the
//! skewed-churn acceptance scenario the degradation-aware arm strictly
//! beats the static-θ* arm on both mean step time and worst straggler
//! gap while the fault-free control never replans.

use dflop::fault::{FaultKind, FaultTrace, FleetHealth};
use dflop::model::catalog::{llama3, llava_ov};
use dflop::shard::partition::ShardedDataset;
use dflop::shard::ShardConfig;
use dflop::sim::{run_system, FaultConfig, RunConfig, RunResult, SystemKind};
use dflop::util::parallel::set_max_threads;
use dflop::util::prop::forall;
use std::sync::Mutex;

/// The pool width is process-global; tests that flip it hold this lock so
/// the two runs being compared really execute at the width they claim.
static WIDTH_LOCK: Mutex<()> = Mutex::new(());

fn width_guard() -> std::sync::MutexGuard<'static, ()> {
    WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The acceptance configuration (shared with `benches/fault_bench.rs`):
/// a 4-shard fleet of single-node replicas, long enough for the scripted
/// scenarios (last heal at iteration 15) plus post-heal iterations.
/// Rebalancing stays on (the default) — since PR 10 the balancer prices
/// items by the confirmed per-shard slowdown, so it composes with the
/// fault-aware batch weighting instead of fighting it.
fn fleet_cfg(trace: &str, respond: bool) -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 18, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: trace.to_string(), respond });
    cfg
}

fn run_fleet(cfg: &RunConfig) -> RunResult {
    let m = llava_ov(llama3("8b"));
    run_system(SystemKind::DflopSharded, &m, "skewed-shard", cfg)
}

#[test]
fn fleet_run_bit_identical_across_thread_counts() {
    let _g = width_guard();
    let cfg = fleet_cfg("skewed-churn", true);
    set_max_threads(1);
    let serial = run_fleet(&cfg);
    set_max_threads(8);
    let parallel = run_fleet(&cfg);
    set_max_threads(0);
    assert_eq!(serial.theta, parallel.theta);
    assert_eq!(
        serial.per_gpu_throughput.to_bits(),
        parallel.per_gpu_throughput.to_bits(),
        "fleet throughput drifted with thread count"
    );
    assert_eq!(
        serial.mean_iteration_time.to_bits(),
        parallel.mean_iteration_time.to_bits()
    );
    assert_eq!(serial.fault, parallel.fault, "fault counters drifted");
    assert_eq!(serial.straggler_gaps.len(), parallel.straggler_gaps.len());
    for (a, b) in serial.straggler_gaps.iter().zip(&parallel.straggler_gaps) {
        assert_eq!(a.to_bits(), b.to_bits(), "straggler gap drifted");
    }
    assert_eq!(serial.replans, parallel.replans);
    let key = |r: &RunResult| -> Vec<_> {
        r.replan_events
            .iter()
            .map(|e| (e.iteration, e.swapped, e.old, e.new))
            .collect()
    };
    assert_eq!(key(&serial), key(&parallel), "replan stream drifted");
}

#[test]
fn none_trace_is_bit_identical_to_a_healthy_run() {
    // The charging paths, the members-aware feed, and the fault-aware
    // policy must all be exactly invisible when the trace has no events:
    // a `faults: Some("none")` run and a `faults: None` run are the same
    // simulation bit for bit.
    let _g = width_guard();
    let with_fleet = run_fleet(&fleet_cfg("none", true));
    let mut plain = fleet_cfg("none", true);
    plain.faults = None;
    let healthy = run_fleet(&plain);
    assert_eq!(
        with_fleet.per_gpu_throughput.to_bits(),
        healthy.per_gpu_throughput.to_bits(),
        "an event-free FaultTrace changed the simulation"
    );
    assert_eq!(
        with_fleet.mean_iteration_time.to_bits(),
        healthy.mean_iteration_time.to_bits()
    );
    assert_eq!(with_fleet.theta, healthy.theta);
    assert_eq!(with_fleet.migrations, healthy.migrations);
    assert_eq!(with_fleet.replans, healthy.replans);
    for (a, b) in with_fleet.straggler_gaps.iter().zip(&healthy.straggler_gaps) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // And the fault ledger of an event-free run is all zeros.
    assert_eq!(with_fleet.fault.failures, 0);
    assert_eq!(with_fleet.fault.recoveries, 0);
    assert_eq!(with_fleet.fault.reshard_events, 0);
    assert_eq!(with_fleet.fault.degraded_iters, 0);
}

#[test]
fn fault_aware_beats_static_under_skewed_churn() {
    // The acceptance criterion: both arms replay the identical
    // skewed-churn FaultTrace (a replica failure, an escalating
    // straggler, a degraded allreduce link — all healing before the end)
    // over skewed shard data; the degradation-aware arm must sustain a
    // strictly faster mean step AND a strictly smaller worst straggler
    // gap, and the fault-free control must never replan.
    let _g = width_guard();
    let aware = run_fleet(&fleet_cfg("skewed-churn", true));
    let stat = run_fleet(&fleet_cfg("skewed-churn", false));
    let control = run_fleet(&fleet_cfg("none", true));
    assert_eq!(control.replans, 0, "fault-free control replanned");
    assert!(
        aware.mean_iteration_time < stat.mean_iteration_time,
        "aware step {:.3}s not below static {:.3}s",
        aware.mean_iteration_time,
        stat.mean_iteration_time
    );
    let worst = |r: &RunResult| r.straggler_gaps.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        worst(&aware) < worst(&stat),
        "worst gap not reduced: {:.3}s vs {:.3}s",
        worst(&aware),
        worst(&stat)
    );
    // Both arms see the same injected physics in the ledger.
    assert_eq!(aware.fault, stat.fault, "arms saw different fault streams");
    assert!(aware.fault.failures >= 1);
    assert!(aware.fault.recoveries >= 1);
    assert!(aware.fault.reshard_events >= 2, "fail + recover each reshard");
    assert!(aware.fault.degraded_iters > 0);
    // Gap percentiles are present and monotone.
    assert_eq!(aware.straggler_gap_percentiles.len(), 3);
    let vs: Vec<f64> = aware.straggler_gap_percentiles.iter().map(|&(_, v)| v).collect();
    assert!(vs.windows(2).all(|w| w[0] <= w[1]), "percentiles not monotone: {vs:?}");
}

#[test]
fn resharding_round_trips_and_counts_conserve_the_batch() {
    // Property: any fail/recover sequence that ends with every slot back
    // up restores the exact healthy membership; and the slowdown-weighted
    // batch split always conserves the global batch with every member
    // getting at least one item.
    forall("shrink-then-grow resharding round-trips", 200, |g| {
        let shards = g.size(7) + 1; // 2..=8
        let mut h = FleetHealth::healthy(shards);
        let mut downed = Vec::new();
        // Shrink: a random set of distinct failures (never the last one).
        for _ in 0..g.size(shards) {
            let s = g.rng.index(shards);
            if h.apply(FaultKind::Fail { shard: s }) {
                downed.push(s);
            }
        }
        let shrunk = h.active();
        let shrink_ok = shrunk.len() == shards - downed.len() && !shrunk.is_empty();
        // Weighted counts over the shrunken fleet conserve the batch.
        let gbs = g.size(256);
        let weights: Vec<f64> = shrunk.iter().map(|_| g.rng.uniform(0.4, 1.0)).collect();
        let counts = ShardedDataset::weighted_counts(gbs, &weights);
        let conserve_ok = counts.iter().sum::<usize>() == gbs
            && (gbs < shrunk.len() || counts.iter().all(|&c| c >= 1));
        // Grow back: recover everything that went down (any order).
        for &s in downed.iter().rev() {
            h.apply(FaultKind::Recover { shard: s });
        }
        let round_trip_ok = h == FleetHealth::healthy(shards);
        (
            format!("shards={shards} downed={downed:?} gbs={gbs} counts={counts:?}"),
            shrink_ok && conserve_ok && round_trip_ok,
        )
    });
}

#[test]
fn traces_are_deterministic_given_key_and_seed() {
    forall("FaultTrace::by_key is a pure function", 40, |g| {
        let shards = g.size(7) + 1;
        let seed = g.rng.range(0, 1 << 20) as u64;
        let ok = FaultTrace::keys().iter().all(|key| {
            FaultTrace::by_key(key, shards, seed) == FaultTrace::by_key(key, shards, seed)
        });
        (format!("shards={shards} seed={seed}"), ok)
    });
}

#[test]
fn fault_validation_rejects_bad_configs_up_front() {
    // Satellite: fault/scenario keys are validated before any profiling
    // or pool work, as `util::error::Result` errors.
    let m = llava_ov(llama3("8b"));
    // Unknown trace key.
    let mut cfg = fleet_cfg("quake", true);
    assert!(dflop::engine::run(SystemKind::DflopSharded, &m, "mixed", &cfg).is_err());
    // Faults on a system with no DP group.
    cfg = fleet_cfg("churn", true);
    cfg.shard = None;
    assert!(dflop::engine::run(SystemKind::Dflop, &m, "mixed", &cfg).is_err());
    // Too few shards to degrade.
    cfg = fleet_cfg("churn", true);
    cfg.shard = Some(ShardConfig { dp_shards: 1, ..ShardConfig::default() });
    assert!(dflop::engine::run(SystemKind::DflopSharded, &m, "mixed", &cfg).is_err());
    // Hetero per-shard plans don't compose with fault injection.
    cfg = fleet_cfg("churn", true);
    cfg.shard = Some(ShardConfig { dp_shards: 4, hetero: true, ..ShardConfig::default() });
    assert!(dflop::engine::run(SystemKind::DflopSharded, &m, "mixed", &cfg).is_err());
    // The happy path still validates.
    cfg = fleet_cfg("churn", true);
    assert!(dflop::engine::validate(SystemKind::DflopSharded, "skewed-shard", &cfg).is_ok());
}
