//! Integration tests for the observability subsystem (PR 8 recording,
//! PR 9 analysis).
//!
//! The contract under test: switching the recorder on changes no bit of
//! the simulation it observes; the Chrome trace and metrics exports are
//! byte-identical at any `DFLOP_THREADS`; the exported trace passes the
//! Trace Event Format schema checks and carries replica-tagged op spans,
//! bubble spans, and fault/replan instant events on the acceptance fleet
//! scenario; the gap-interval bubble accounting agrees bit-exactly
//! with the simulator's own `stage_busy`/`stage_idle` aggregates; the
//! critical-path chain telescopes bit-exactly to the recorded makespan
//! on real engine runs; and the predicted-vs-measured audit is present,
//! internally consistent, and byte-identical across thread counts.

use dflop::model::catalog::{llama3, llava_ov};
use dflop::obs::bubble::{iteration_bubble_fraction, stage_bubbles, Gap};
use dflop::obs::chrome::{trace_json, validate_trace, CLUSTER_PID};
use dflop::obs::critical::{critical_path, op_slack};
use dflop::obs::{run_result_json, ObsConfig};
use dflop::shard::ShardConfig;
use dflop::sim::{run_system, FaultConfig, RunConfig, RunResult, SystemKind};
use dflop::util::json::{emit, parse, Json};
use dflop::util::parallel::set_max_threads;
use std::collections::BTreeSet;
use std::sync::Mutex;

/// The pool width is process-global; tests that flip it hold this lock so
/// the two runs being compared really execute at the width they claim.
static WIDTH_LOCK: Mutex<()> = Mutex::new(());

fn width_guard() -> std::sync::MutexGuard<'static, ()> {
    WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The ISSUE acceptance fleet: a 4-shard fleet of single-node replicas
/// replaying the skewed-churn FaultTrace over skewed shard data (the
/// `tests/fleet.rs` scenario), here with the recorder switched on.
fn fleet_cfg(obs: Option<ObsConfig>) -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 18, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: "skewed-churn".to_string(), respond: true });
    cfg.obs = obs;
    cfg
}

fn run_fleet(obs: Option<ObsConfig>) -> RunResult {
    let m = llava_ov(llama3("8b"));
    run_system(SystemKind::DflopSharded, &m, "skewed-shard", &fleet_cfg(obs))
}

const FULL: ObsConfig = ObsConfig { timelines: true, metrics: true, audit: false };

#[test]
fn recorder_on_leaves_the_simulation_bit_identical() {
    // Zero-overhead-off has a stronger sibling: recorder-*on* feeds no
    // value back into the simulation, so every statistic of an observed
    // run matches the unobserved run to the bit.
    let _g = width_guard();
    let off = run_fleet(None);
    let on = run_fleet(Some(FULL));
    assert!(off.obs.is_none(), "recorder-off run must carry no log");
    let log = on.obs.as_ref().expect("recorder-on run must carry a log");
    assert_eq!(log.iterations.len(), 18);
    assert!(log.metrics.is_some());
    assert_eq!(off.theta, on.theta);
    assert_eq!(off.per_gpu_throughput.to_bits(), on.per_gpu_throughput.to_bits());
    assert_eq!(off.mean_iteration_time.to_bits(), on.mean_iteration_time.to_bits());
    assert_eq!(off.mean_idle.to_bits(), on.mean_idle.to_bits());
    assert_eq!(off.migrations, on.migrations);
    assert_eq!(off.replans, on.replans);
    assert_eq!(off.fault, on.fault);
    assert_eq!(off.straggler_gaps.len(), on.straggler_gaps.len());
    for (a, b) in off.straggler_gaps.iter().zip(&on.straggler_gaps) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // The recorder's sim clock is the sum of the step times it saw.
    let total: f64 = on.iterations.iter().map(|s| s.iteration_time).sum();
    assert_eq!(log.sim_now.to_bits(), total.to_bits());
}

#[test]
fn trace_and_metrics_byte_identical_across_thread_counts() {
    let _g = width_guard();
    set_max_threads(1);
    let serial = run_fleet(Some(FULL));
    set_max_threads(8);
    let parallel = run_fleet(Some(FULL));
    set_max_threads(0);
    let (ls, lp) = (
        serial.obs.as_ref().expect("log"),
        parallel.obs.as_ref().expect("log"),
    );
    let (ts, tp) = (trace_json(ls), trace_json(lp));
    assert_eq!(ts, tp, "Chrome trace drifted with thread count");
    let ms = ls.metrics.as_ref().expect("metrics").dump();
    let mp = lp.metrics.as_ref().expect("metrics").dump();
    assert_eq!(ms, mp, "metrics dump drifted with thread count");
    // The summary export is deterministic too once wall-clock is excluded;
    // spot-check a field that flows through every layer.
    let a = parse(&run_result_json(&serial)).expect("summary json");
    let b = parse(&run_result_json(&parallel)).expect("summary json");
    assert_eq!(a.get("mean_iteration_time_s"), b.get("mean_iteration_time_s"));
    assert_eq!(a.get("fault"), b.get("fault"));
}

#[test]
fn fleet_trace_is_schema_valid_with_expected_lanes_and_events() {
    let _g = width_guard();
    let r = run_fleet(Some(FULL));
    let log = r.obs.as_ref().expect("log");
    let text = trace_json(log);
    validate_trace(&text).expect("schema-valid Chrome trace");
    let doc = parse(&text).expect("valid json");
    let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    let cat = |e: &Json| e.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
    let name = |e: &Json| e.get("name").and_then(Json::as_str).unwrap_or("").to_string();
    // Op spans are tagged with their replica as the pid, below the
    // synthetic cluster pid; the 4-shard fleet must show several.
    let op_replicas: BTreeSet<usize> = evs
        .iter()
        .filter(|e| cat(e) == "op")
        .map(|e| e.get("pid").and_then(Json::as_usize).expect("op pid"))
        .collect();
    assert!(
        op_replicas.len() > 1,
        "expected multiple replica lanes, got {op_replicas:?}"
    );
    assert!(op_replicas.iter().all(|&p| p < CLUSTER_PID));
    assert!(evs.iter().any(|e| cat(e) == "bubble"), "no bubble spans");
    assert!(
        evs.iter().any(|e| name(e) == "allreduce"),
        "no allreduce spans from the step barrier"
    );
    let names: BTreeSet<String> = evs.iter().map(&name).collect();
    assert!(names.contains("fault"), "skewed-churn must emit fault instants");
    // Every replan decision (swap or keep) appears as one instant event.
    let replan_instants = evs
        .iter()
        .filter(|e| matches!(name(e).as_str(), "replan" | "replan-kept" | "refit-retry"))
        .count();
    assert_eq!(replan_instants, r.replan_events.len());
}

#[test]
fn metrics_only_config_skips_timelines_but_counts_faults() {
    let _g = width_guard();
    let r = run_fleet(Some(ObsConfig { timelines: false, metrics: true, audit: false }));
    let log = r.obs.as_ref().expect("log");
    assert!(
        log.iterations.iter().all(|it| it.replicas.is_empty()),
        "timelines captured despite timelines=false"
    );
    let reg = log.metrics.as_ref().expect("metrics");
    assert_eq!(reg.counter("iterations"), 18);
    assert_eq!(reg.counter("fault_failures"), r.fault.failures as u64);
    assert_eq!(reg.counter("fault_recoveries"), r.fault.recoveries as u64);
    let swapped = r.replan_events.iter().filter(|e| e.swapped).count() as u64;
    assert_eq!(reg.counter("replans"), swapped);
    assert_eq!(reg.snapshots().len(), 18);
}

#[test]
fn bubble_accounting_is_bit_exact_against_the_simulator() {
    // Megatron is budget-free (no ILP deadline) and single-replica, so
    // its iterations retain their op timelines; the gap extraction must
    // reproduce the simulator's own busy/idle aggregates bit for bit,
    // and the intervals must tile the idle time up to float associativity.
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 32, 3, 42);
    cfg.profile_samples = 256;
    cfg.obs = Some(ObsConfig { timelines: true, metrics: false, audit: false });
    let r = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
    assert!(!r.iterations.is_empty());
    for it in &r.iterations {
        assert!(!it.timeline.is_empty(), "single-replica run must keep timelines");
        let sb = stage_bubbles(&it.timeline, it.n_stages, it.pipeline_makespan, &it.stage_busy);
        assert_eq!(sb.busy.len(), it.n_stages);
        for (s, (a, b)) in sb.busy.iter().zip(&it.stage_busy).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "busy drifted at stage {s}");
        }
        for (s, (a, b)) in sb.idle.iter().zip(&it.stage_idle).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "idle drifted at stage {s}");
        }
        assert_eq!(
            sb.bubble_fraction().to_bits(),
            iteration_bubble_fraction(it).to_bits()
        );
        for s in 0..it.n_stages {
            let gap_sum: f64 =
                sb.gaps.iter().filter(|g| g.stage == s).map(Gap::len).sum();
            let tol = 1e-9 * it.pipeline_makespan.max(1.0);
            assert!(
                (gap_sum - sb.idle[s]).abs() <= tol,
                "stage {s}: gap intervals sum to {gap_sum}, idle is {}",
                sb.idle[s]
            );
        }
        for g in &sb.gaps {
            assert!(!g.is_empty(), "degenerate gap {g:?}");
            assert!(g.start >= 0.0 && g.end <= it.pipeline_makespan, "gap {g:?} out of span");
        }
        // Sorted by stage; time-ordered within a stage.
        assert!(sb.gaps.windows(2).all(|w| {
            w[0].stage < w[1].stage || (w[0].stage == w[1].stage && w[0].end <= w[1].start)
        }));
    }
    // The recorder's single-replica fallback captured the same timelines.
    let log = r.obs.as_ref().expect("log");
    for (it, rec) in r.iterations.iter().zip(&log.iterations) {
        assert_eq!(rec.replicas.len(), 1);
        assert_eq!(rec.replicas[0].timeline, it.timeline);
    }
}

#[test]
fn run_summary_json_parses_with_expected_fields() {
    let _g = width_guard();
    let r = run_fleet(Some(FULL));
    let doc = parse(&run_result_json(&r)).expect("summary must be valid json");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some("dflop-run-v1"));
    assert_eq!(doc.get("system").and_then(Json::as_str), Some(r.system.label()));
    assert_eq!(doc.get("n_gpus").and_then(Json::as_usize), Some(r.n_gpus));
    assert_eq!(
        doc.path("fault.failures").and_then(Json::as_usize),
        Some(r.fault.failures)
    );
    assert_eq!(
        doc.get("iteration_time_s").and_then(Json::as_arr).map(<[Json]>::len),
        Some(r.iterations.len())
    );
    assert_eq!(
        doc.get("replan_events").and_then(Json::as_arr).map(<[Json]>::len),
        Some(r.replan_events.len())
    );
    // Wall-clock lives only under its labelled key, never in the
    // deterministic body.
    assert!(doc.path("wall_clock.optimizer_s").is_some());
    assert!(doc.get("mean_iteration_time_s").and_then(Json::as_f64).is_some());
}

// ------------------------------------------------------------------
// PR 9 — critical path, audit, long-horizon fault scenarios
// ------------------------------------------------------------------

#[test]
fn critical_path_is_bit_exact_on_engine_runs() {
    // The chain property holds on real engine timelines, not just the
    // randomized property-test workloads: span durations telescope to
    // the recorded makespan bit pattern, the chain tiles [0, makespan]
    // with no gap, and slack is zero exactly on the chain.
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 32, 3, 42);
    cfg.profile_samples = 256;
    let r = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
    assert!(!r.iterations.is_empty());
    let enc_stages = r.theta.enc.dp * r.theta.enc.pp;
    for it in &r.iterations {
        let cp = critical_path(&it.timeline, it.n_stages, it.pipeline_makespan)
            .expect("engine timeline must yield a chain");
        assert_eq!(
            cp.total().to_bits(),
            it.pipeline_makespan.to_bits(),
            "chain does not telescope to the makespan"
        );
        let first = cp.spans.first().expect("non-empty chain");
        assert_eq!(first.start.to_bits(), 0f64.to_bits());
        for w in cp.spans.windows(2) {
            assert_eq!(w[0].end.to_bits(), w[1].start.to_bits(), "chain has a seam");
        }
        let (enc, llm, comm) = cp.modality_blame(enc_stages);
        let tol = 1e-9 * it.pipeline_makespan.max(1.0);
        assert!(
            (enc + llm + comm - cp.total()).abs() <= tol,
            "modality blame does not partition the chain"
        );
        let slacks = op_slack(&it.timeline, it.n_stages, it.pipeline_makespan);
        assert_eq!(slacks.len(), it.timeline.len());
        assert!(slacks.iter().any(|s| s.critical), "no op marked critical");
        for s in &slacks {
            assert!(s.slack >= 0.0, "negative slack at stage {}", s.stage);
            if s.critical {
                assert_eq!(s.slack.to_bits(), 0f64.to_bits());
            }
        }
    }
}

/// The audit acceptance run: adaptive replanning over the drifting
/// curriculum stream, with batch recording + audit on.
fn audit_cfg() -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 24, 42);
    cfg.profile_samples = 256;
    cfg.obs = Some(ObsConfig { timelines: false, metrics: true, audit: true });
    cfg
}

#[test]
fn audit_report_is_present_and_internally_consistent() {
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    let r = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &audit_cfg());
    let log = r.obs.as_ref().expect("log");
    let a = log.audit.as_ref().expect("audit-enabled run must record a report");
    // One row per iteration, measured straight from the simulator.
    assert_eq!(a.rows.len(), r.iterations.len());
    for (row, it) in a.rows.iter().zip(&r.iterations) {
        assert_eq!(row.measured.to_bits(), it.iteration_time.to_bits());
        assert!(row.predicted > 0.0, "estimator predicted a non-positive step");
        assert_eq!(row.residual.to_bits(), (row.predicted - row.measured).to_bits());
    }
    assert!(a.mean_abs_rel_err.is_finite() && a.mean_abs_rel_err >= 0.0);
    assert!(a.bias.is_finite());
    // One counterfactual attribution per adopted swap, windows non-empty.
    let swaps = r.replan_events.iter().filter(|e| e.swapped).count();
    assert_eq!(a.replans.len(), swaps);
    for ra in &a.replans {
        assert!(ra.window > 0);
        assert!(ra.incumbent_mean > 0.0 && ra.adopted_mean > 0.0);
        assert_eq!(
            ra.measured_benefit.to_bits(),
            (ra.incumbent_mean - ra.adopted_mean).to_bits()
        );
    }
    // Metrics wiring.
    let reg = log.metrics.as_ref().expect("metrics");
    assert_eq!(reg.counter("audit_rows"), a.rows.len() as u64);
    assert_eq!(reg.counter("audit_replans"), a.replans.len() as u64);
    // The --json summary carries the audit section.
    let doc = parse(&run_result_json(&r)).expect("summary json");
    assert_eq!(doc.path("audit.schema").and_then(Json::as_str), Some("dflop-audit-v1"));
    assert_eq!(
        doc.path("audit.rows").and_then(Json::as_arr).map(<[Json]>::len),
        Some(a.rows.len())
    );
}

#[test]
fn audit_output_byte_identical_across_thread_counts() {
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    set_max_threads(1);
    let serial = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &audit_cfg());
    set_max_threads(8);
    let parallel = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &audit_cfg());
    set_max_threads(0);
    let audit_text = |r: &RunResult| {
        emit(&dflop::obs::audit::audit_json(
            r.obs.as_deref().and_then(|l| l.audit.as_ref()).expect("audit report"),
        ))
    };
    assert_eq!(
        audit_text(&serial),
        audit_text(&parallel),
        "audit export drifted with thread count"
    );
}

/// The long-horizon scenario: the seeded ~512-iteration churn generator
/// replayed over a 48-iteration fleet window (satellite of PR 9).
fn long_fleet_cfg(obs: Option<ObsConfig>) -> RunConfig {
    let mut cfg = RunConfig::new(1, 48, 48, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: "long-horizon".to_string(), respond: true });
    cfg.obs = obs;
    cfg
}

fn run_long_fleet(obs: Option<ObsConfig>) -> RunResult {
    let m = llava_ov(llama3("8b"));
    run_system(SystemKind::DflopSharded, &m, "skewed-shard", &long_fleet_cfg(obs))
}

#[test]
fn long_horizon_fault_trace_is_schema_valid_with_matching_counters() {
    let _g = width_guard();
    let r = run_long_fleet(Some(FULL));
    let log = r.obs.as_ref().expect("log");
    let text = trace_json(log);
    validate_trace(&text).expect("schema-valid Chrome trace under long-horizon churn");
    let reg = log.metrics.as_ref().expect("metrics");
    assert_eq!(reg.counter("iterations"), 48);
    // Counters mirror the run's own fault accounting exactly.
    assert_eq!(reg.counter("fault_failures"), r.fault.failures as u64);
    assert_eq!(reg.counter("fault_recoveries"), r.fault.recoveries as u64);
    if r.fault.failures + r.fault.recoveries > 0 {
        let doc = parse(&text).expect("valid json");
        let evs = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert!(
            evs.iter()
                .any(|e| e.get("name").and_then(Json::as_str) == Some("fault")),
            "fault counters non-zero but no fault instants in the trace"
        );
    }
}

#[test]
fn long_horizon_trace_and_metrics_byte_identical_across_thread_counts() {
    let _g = width_guard();
    set_max_threads(1);
    let serial = run_long_fleet(Some(FULL));
    set_max_threads(8);
    let parallel = run_long_fleet(Some(FULL));
    set_max_threads(0);
    let (ls, lp) = (
        serial.obs.as_ref().expect("log"),
        parallel.obs.as_ref().expect("log"),
    );
    assert_eq!(
        trace_json(ls),
        trace_json(lp),
        "long-horizon Chrome trace drifted with thread count"
    );
    assert_eq!(
        ls.metrics.as_ref().expect("metrics").dump(),
        lp.metrics.as_ref().expect("metrics").dump(),
        "long-horizon metrics dump drifted with thread count"
    );
}
