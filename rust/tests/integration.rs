//! Cross-module integration tests: full-system runs over the simulated
//! cluster, optimizer-vs-exhaustive checks, figure harness smoke tests, and
//! end-to-end invariants that only hold when every layer composes.

use dflop::data::dataset::Dataset;
use dflop::figures::{by_id, FigOpts};
use dflop::model::catalog::{llava_ov, llama3, paper_configs};
use dflop::optimizer::plan::find_combs;
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use dflop::sim::{run_system, RunConfig, SystemKind};
use dflop::util::prop::forall;

fn quick_cfg(nodes: usize, gbs: usize) -> RunConfig {
    let mut c = RunConfig::new(nodes, gbs, 3, 42);
    c.profile_samples = 256;
    c
}

#[test]
fn dflop_wins_on_every_paper_configuration() {
    // The headline claim (Fig 7): DFLOP ≥ both baselines on every Table-3
    // configuration, gains within the paper's reported band.
    let cfg = quick_cfg(4, 128);
    for pc in paper_configs() {
        let d = run_system(SystemKind::Dflop, &pc.mllm, "mixed", &cfg);
        let mg = run_system(SystemKind::Megatron, &pc.mllm, "mixed", &cfg);
        let pt = run_system(SystemKind::Pytorch, &pc.mllm, "mixed", &cfg);
        let vs_mega = d.speedup_over(&mg);
        let vs_torch = d.speedup_over(&pt);
        assert!(vs_mega > 1.0, "{}: vs Megatron {vs_mega:.2}", pc.label);
        assert!(vs_torch > 1.0, "{}: vs PyTorch {vs_torch:.2}", pc.label);
        assert!(
            vs_mega.max(vs_torch) < 4.5,
            "{}: implausible gain {:.2}",
            pc.label,
            vs_mega.max(vs_torch)
        );
    }
}

#[test]
fn dflop_reduces_idle_time_substantially() {
    // Fig 13: idle-time reduction vs both baselines.
    let cfg = quick_cfg(4, 128);
    let m = llava_ov(llama3("8b"));
    let d = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
    let mg = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
    let pt = run_system(SystemKind::Pytorch, &m, "mixed", &cfg);
    assert!(
        d.mean_idle < 0.7 * mg.mean_idle,
        "DFLOP idle {:.1} vs Megatron {:.1}",
        d.mean_idle,
        mg.mean_idle
    );
    assert!(
        d.mean_idle < 0.5 * pt.mean_idle,
        "DFLOP idle {:.1} vs PyTorch {:.1}",
        d.mean_idle,
        pt.mean_idle
    );
}

#[test]
fn gap_does_not_collapse_with_scale() {
    // Fig 12's direction: the DFLOP advantage persists as nodes grow.
    let m = llava_ov(llama3("8b"));
    let mut gains = Vec::new();
    for nodes in [1usize, 4] {
        let cfg = quick_cfg(nodes, 32 * nodes);
        let d = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let mg = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        gains.push(d.speedup_over(&mg));
    }
    assert!(gains[1] > gains[0] * 0.85, "gap collapsed: {gains:?}");
}

#[test]
fn optimizer_beats_every_random_feasible_candidate() {
    // θ* must score at least as well (in realized simulation) as a sample
    // of random feasible alternatives — an adversarial sanity check on
    // Algorithm 1's objective.
    let m = llava_ov(llama3("8b"));
    let cluster = ClusterSpec::hgx_a100(1);
    let truth = Truth::new(cluster);
    let mut backend = SimBackend::new(truth.clone());
    let profile =
        ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(5);
    let data = profile_data(&m, &mut ds, 256);
    let gbs = 32;
    let inp = OptimizerInputs {
        m: &m,
        profile: &profile,
        data: &data,
        n_gpus: 8,
        gpus_per_node: 8,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs,
        assume_balanced: true,
    };
    let star = optimize(&inp).expect("feasible");

    // Simulated realized time of a θ via balanced scheduling.
    let realized = |theta: dflop::optimizer::plan::Theta| -> f64 {
        use dflop::pipeline::build::{iterate, SystemPlan};
        use dflop::profiling::estimator::Estimator;
        use dflop::scheduler::correction::{Correction, CorrectionConfig};
        use dflop::scheduler::online::{OnlineScheduler, SchedulerConfig};
        let est = Estimator::new(&m, &profile.throughput);
        let sched = OnlineScheduler::new(
            theta,
            SchedulerConfig::default(),
            Correction::new(CorrectionConfig::default()),
        );
        let mut ds = Dataset::mixed(77);
        let mut total = 0.0;
        for _ in 0..3 {
            let shapes = ds.shaped_batch(&m, gbs);
            let s = sched.schedule(&est, &shapes);
            let buckets: Vec<Vec<_>> = s
                .assignment
                .buckets
                .iter()
                .map(|g| g.iter().map(|&i| shapes[i]).collect())
                .collect();
            let plan = SystemPlan { m: &m, truth: &truth, theta };
            total += iterate(&plan, &buckets).iteration_time;
        }
        total
    };
    let star_time = realized(star.theta);

    // A handful of alternative feasible candidates.
    let mut rng = dflop::util::rng::Rng::new(3);
    let mut checked = 0;
    for _ in 0..40 {
        let e_gpus = rng.range(1, 7) as usize;
        let l_gpus = 8 - e_gpus;
        let e_combs = find_combs(e_gpus, 8, m.encoder.layers);
        let l_combs = find_combs(l_gpus, 8, m.llm.layers);
        if e_combs.is_empty() || l_combs.is_empty() {
            continue;
        }
        let enc = *rng.choose(&e_combs);
        let llm = *rng.choose(&l_combs);
        if enc.dp % llm.dp != 0 && llm.dp % enc.dp != 0 {
            continue;
        }
        let n_mb = (rng.range(1, (gbs / llm.dp).max(1) as i64)) as usize;
        let theta = dflop::optimizer::plan::Theta { enc, llm, n_mb };
        let t = realized(theta);
        checked += 1;
        assert!(
            star_time <= t * 1.25,
            "random candidate {theta} realized {t:.2}s beats θ* {star_time:.2}s by >25%"
        );
    }
    assert!(checked > 10, "too few candidates checked: {checked}");
}

#[test]
fn find_combs_is_exhaustive() {
    forall("find_combs exhaustive", 100, |g| {
        let gpus = g.size(48);
        let combs = find_combs(gpus, 8, 64);
        // Every returned combo multiplies out; brute-force count matches.
        let mut expect = 0;
        for tp in [1usize, 2, 4, 8] {
            if gpus % tp != 0 {
                continue;
            }
            let rest = gpus / tp;
            for pp in 1..=rest.min(64) {
                if rest % pp == 0 {
                    expect += 1;
                }
            }
        }
        (
            format!("gpus={gpus} combs={} expect={expect}", combs.len()),
            combs.len() == expect && combs.iter().all(|c| c.gpus() == gpus),
        )
    });
}

#[test]
fn figure_harness_smoke() {
    // Each quick figure produces non-empty output with its own header.
    let mut o = FigOpts::default();
    o.nodes = 1;
    o.gbs = 32;
    o.iters = 2;
    for (id, needle) in [
        ("1", "Fig 1"),
        ("2", "Fig 2a"),
        ("4", "Fig 4"),
        ("13", "Fig 13"),
        ("16", "Fig 16a"),
    ] {
        let text = by_id(id, &o).expect("known figure id");
        assert!(text.contains(needle), "figure {id} missing header");
        assert!(text.len() > 100, "figure {id} suspiciously short");
    }
    assert!(by_id("99", &o).is_none());
}

#[test]
fn correction_pays_off_under_heavy_anomalies() {
    // Fig 15's positive corner: high anomaly rate × high latency ⇒ the
    // corrected scheduler must not be slower than the uncorrected one.
    let m = llava_ov(llama3("8b"));
    let mut ds = Dataset::mixed(42);
    let probe = ds.shaped_batch(&m, 256);
    let mut buckets: Vec<u64> = probe
        .iter()
        .map(|s| Truth::llm_bucket(s.llm_seq as f64))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    let injected: Vec<(u64, f64)> = buckets
        .iter()
        .step_by(6)
        .map(|&b| (b, 0.45))
        .collect();
    let mut on = quick_cfg(2, 96);
    on.iters = 10;
    on.injected = injected.clone();
    let mut off = on.clone();
    off.disable_correction = true;
    let r_on = run_system(SystemKind::Dflop, &m, "mixed", &on);
    let r_off = run_system(SystemKind::Dflop, &m, "mixed", &off);
    let steady = |r: &dflop::sim::RunResult| {
        r.iterations[4..]
            .iter()
            .map(|s| s.iteration_time)
            .sum::<f64>()
    };
    // Allow a small tolerance: the paper's own Fig 15 shows the benefit
    // can be marginal; what must not happen is a large regression.
    assert!(
        steady(&r_on) <= steady(&r_off) * 1.03,
        "correction hurt: on {:.2} off {:.2}",
        steady(&r_on),
        steady(&r_off)
    );
}
