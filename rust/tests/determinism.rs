//! Thread-count-independence tests for the parallel evaluation substrate.
//!
//! The `util::parallel` pool is threaded through the optimizer's Phase-2
//! scan and Eq-1 refinement, the simulator's evaluation cells, and the ILP
//! scheduler's root split. The contract is that none of that is allowed to
//! change a single bit of output: the same seed must produce identical
//! results at `--threads 1` and `--threads 8`. (The one documented
//! exception is an ILP call whose *budget expires* — the incumbent then
//! depends on wall-clock, exactly as it did in the serial solver — so the
//! ILP check below uses an instance the budget comfortably exhausts.)

use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llama3, llava_ov};
use dflop::optimizer::plan::Theta;
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use dflop::pipeline::{simulate, simulate_reference, Route, SimWorkspace};
use dflop::scheduler::ilp;
use dflop::scheduler::lpt::ItemCost;
use dflop::shard::ShardConfig;
use dflop::sim::{run_cells, run_system, Cell, RunConfig, SystemKind};
use dflop::stream::replan::{ReplanConfig, ReplanContext, Replanner};
use dflop::util::parallel::set_max_threads;
use dflop::util::rng::Rng;
use std::sync::Mutex;
use std::time::Duration;

/// The pool width is process-global; tests that flip it hold this lock so
/// the two runs being compared really execute at the width they claim.
static WIDTH_LOCK: Mutex<()> = Mutex::new(());

fn width_guard() -> std::sync::MutexGuard<'static, ()> {
    WIDTH_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn optimizer_theta_identical_across_thread_counts() {
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    let cluster = ClusterSpec::hgx_a100(2);
    let mut backend = SimBackend::new(Truth::new(cluster));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(1234);
    let data = profile_data(&m, &mut ds, 256);
    let inp = OptimizerInputs {
        m: &m,
        profile: &profile,
        data: &data,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: 64,
        assume_balanced: true,
    };
    set_max_threads(1);
    let serial = optimize(&inp).expect("feasible");
    set_max_threads(8);
    let parallel = optimize(&inp).expect("feasible");
    set_max_threads(0);
    assert_eq!(serial.theta, parallel.theta);
    assert_eq!(
        serial.expected_makespan.to_bits(),
        parallel.expected_makespan.to_bits(),
        "Eq-1 score drifted: {} vs {}",
        serial.expected_makespan,
        parallel.expected_makespan
    );
    assert_eq!(serial.candidates_scanned, parallel.candidates_scanned);
    assert_eq!(serial.memory_rejected, parallel.memory_rejected);
}

#[test]
fn simulated_runs_identical_across_thread_counts() {
    let _g = width_guard();
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 32, 3, 42);
    cfg.profile_samples = 256;
    // Megatron/PyTorch cover the baseline path, optimizer-only covers the
    // Algorithm-1 path inside a cell; all three are budget-free (no ILP
    // deadline), so their statistics must match to the bit.
    let cells: Vec<Cell> = [
        SystemKind::Megatron,
        SystemKind::Pytorch,
        SystemKind::DflopOptimizerOnly,
    ]
    .into_iter()
    .map(|kind| Cell {
        kind,
        m: m.clone(),
        dataset: "mixed".to_string(),
        cfg: cfg.clone(),
    })
    .collect();
    set_max_threads(1);
    let serial = run_cells(&cells).expect("known dataset keys");
    set_max_threads(8);
    let parallel = run_cells(&cells).expect("known dataset keys");
    set_max_threads(0);
    assert_eq!(serial.len(), parallel.len());
    for (cell, (a, b)) in cells.iter().zip(serial.iter().zip(&parallel)) {
        assert_eq!(a.theta, b.theta, "{:?}", cell.kind);
        assert_eq!(
            a.per_gpu_throughput.to_bits(),
            b.per_gpu_throughput.to_bits(),
            "{:?}: {} vs {}",
            cell.kind,
            a.per_gpu_throughput,
            b.per_gpu_throughput
        );
        assert_eq!(
            a.mean_iteration_time.to_bits(),
            b.mean_iteration_time.to_bits(),
            "{:?}",
            cell.kind
        );
        assert_eq!(a.mean_idle.to_bits(), b.mean_idle.to_bits(), "{:?}", cell.kind);
        assert_eq!(a.lpt_fallbacks, b.lpt_fallbacks, "{:?}", cell.kind);
    }
}

#[test]
fn sim_workspace_reuse_identical_to_fresh_runs() {
    // The event-driven 1F1B core keeps all state in a reusable
    // SimWorkspace arena. The contract extended here: reusing one
    // workspace across calls of *different* shapes (more stages, fewer
    // routes, empty sets) must leave no stale state behind — every run is
    // bit-identical to a fresh workspace, and to the retained polling
    // oracle. No width lock needed: the core is serial.
    let mut rng = Rng::new(0x51u64);
    let mut workloads: Vec<(usize, Vec<Route>)> = Vec::new();
    for &(n_stages, n_routes) in
        &[(12usize, 48usize), (3, 4), (16, 64), (1, 1), (16, 64), (5, 0)]
    {
        let routes: Vec<Route> = (0..n_routes)
            .map(|_| {
                let depth = 1 + rng.index(n_stages);
                let mut pool: Vec<usize> = (0..n_stages).collect();
                rng.shuffle(&mut pool);
                let mut stages: Vec<usize> = pool.into_iter().take(depth).collect();
                stages.sort_unstable();
                Route {
                    fwd: (0..depth).map(|_| rng.uniform(0.2, 2.0)).collect(),
                    bwd: (0..depth).map(|_| rng.uniform(0.5, 4.0)).collect(),
                    comm: (0..depth)
                        .map(|p| if p == 0 { 0.0 } else { rng.uniform(0.0, 0.3) })
                        .collect(),
                    stages,
                }
            })
            .collect();
        workloads.push((n_stages, routes));
    }
    let mut ws = SimWorkspace::new();
    for (n_stages, routes) in &workloads {
        ws.routes.clear();
        for r in routes {
            ws.routes.push_route(r);
        }
        let makespan = ws.run(*n_stages, true);
        let fresh = simulate(*n_stages, routes);
        let oracle = simulate_reference(*n_stages, routes);
        assert_eq!(makespan.to_bits(), fresh.makespan.to_bits());
        assert_eq!(makespan.to_bits(), oracle.makespan.to_bits());
        assert_eq!(ws.stage_busy().len(), oracle.stage_busy.len());
        for (a, b) in ws.stage_busy().iter().zip(&oracle.stage_busy) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Fresh-workspace timeline must match the reused one exactly
        // (same engine, same order); the oracle interleaves stages
        // differently, so only its per-stage aggregates are compared.
        assert_eq!(ws.timeline(), &fresh.timeline[..]);
        assert_eq!(ws.timeline().len(), oracle.timeline.len());
    }
}

#[test]
fn drift_replans_identical_across_thread_counts() {
    let _g = width_guard();
    // The stream pipeline end to end: curriculum batches → window/sketch
    // aggregation → drift confirmation → warm-started optimizer replan
    // (the part that fans out over the pool). Every event — trigger
    // iteration, drift statistics, replacement θ, Eq-1 score bits — must
    // be identical at --threads 1 and 8. The Online Scheduler's ILP is
    // deliberately not in this loop (its deadline incumbents are
    // wall-clock-dependent, as documented); the replan path itself is
    // budget-free.
    let m = llava_ov(llama3("8b"));
    let cluster = ClusterSpec::hgx_a100(1);
    let mut backend = SimBackend::new(Truth::new(cluster));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let data = profile_data(&m, &mut Dataset::curriculum(7 ^ 0xDA7A), 256);
    let rctx = ReplanContext {
        m: &m,
        profile: &profile,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: 48,
    };
    let inp = rctx.inputs(&data);
    type Fired = Vec<(usize, Theta, Theta, bool, u64, u64, u64)>;
    let run = |theta0: Theta| -> (Theta, Fired) {
        let mut cfg = ReplanConfig {
            window_batches: 4,
            cooldown: 4,
            ..ReplanConfig::default()
        };
        cfg.drift.confirm = 1;
        let mut rp = Replanner::new(&data, theta0, cfg);
        let mut ds = Dataset::curriculum(7);
        for _ in 0..16 {
            let batch = ds.shaped_batch(&m, 48);
            rp.observe_batch(&rctx, &batch);
        }
        let events = rp
            .events
            .iter()
            .map(|e| {
                (
                    e.iteration,
                    e.old,
                    e.new,
                    e.swapped,
                    e.expected_makespan.to_bits(),
                    e.stat.quantile_dist.to_bits(),
                    e.stat.mix_tv.to_bits(),
                )
            })
            .collect();
        (rp.theta, events)
    };
    set_max_threads(1);
    let theta0_serial = optimize(&inp).expect("feasible").theta;
    let serial = run(theta0_serial);
    set_max_threads(8);
    let theta0_parallel = optimize(&inp).expect("feasible").theta;
    let parallel = run(theta0_parallel);
    set_max_threads(0);
    assert_eq!(theta0_serial, theta0_parallel);
    assert!(
        !serial.1.is_empty(),
        "curriculum ramp must confirm at least one drift"
    );
    assert_eq!(serial.1, parallel.1, "replan event streams drifted");
    assert_eq!(serial.0, parallel.0, "final plans drifted");
}

#[test]
fn sharded_run_identical_across_thread_counts() {
    let _g = width_guard();
    // The shard subsystem end to end on the skewed scenario: per-shard
    // batch synthesis → global stats merge → skew gate → bounded
    // migration → per-replica LPT + pipeline sims fanned over the pool →
    // step barrier. The path is budget-free by construction (per-shard
    // LPT, no ILP deadline), so *every* statistic — rebalance decisions
    // (migration count), replan events, straggler gaps, throughput — must
    // be bit-identical at --threads 1 and 8. The fan-out also hands the
    // replicas to different workers in different interleavings at the two
    // widths, so agreement here is simultaneously the
    // shard-evaluation-order invariance check (the merge itself is
    // order-invariant by the integer-monoid property test in
    // `shard::agg`).
    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(1, 48, 12, 42);
    cfg.profile_samples = 256;
    cfg.shard = Some(ShardConfig {
        dp_shards: 4,
        window_batches: 4,
        ..ShardConfig::default()
    });
    set_max_threads(1);
    let serial = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &cfg);
    set_max_threads(8);
    let parallel = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &cfg);
    set_max_threads(0);
    assert_eq!(serial.theta, parallel.theta);
    assert!(serial.migrations > 0, "skew must exercise the rebalance path");
    assert_eq!(serial.migrations, parallel.migrations, "rebalance decisions drifted");
    assert_eq!(serial.straggler_gaps.len(), parallel.straggler_gaps.len());
    for (i, (a, b)) in serial
        .straggler_gaps
        .iter()
        .zip(&parallel.straggler_gaps)
        .enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "straggler gap drifted at iter {i}");
    }
    assert_eq!(
        serial.per_gpu_throughput.to_bits(),
        parallel.per_gpu_throughput.to_bits()
    );
    assert_eq!(
        serial.mean_iteration_time.to_bits(),
        parallel.mean_iteration_time.to_bits()
    );
    assert_eq!(serial.replans, parallel.replans);
    let events = |r: &dflop::sim::RunResult| -> Vec<(usize, Theta, Theta, bool, u64)> {
        r.replan_events
            .iter()
            .map(|e| (e.iteration, e.old, e.new, e.swapped, e.expected_makespan.to_bits()))
            .collect()
    };
    assert_eq!(events(&serial), events(&parallel), "replan event streams drifted");
}

#[test]
fn ilp_assignment_identical_across_thread_counts() {
    let _g = width_guard();
    // Small enough that the branch-and-bound always exhausts the space
    // within the budget — the regime where the root-split merge promises
    // bitwise agreement.
    let mut rng = Rng::new(99);
    let items: Vec<ItemCost> = (0..12)
        .map(|_| ItemCost {
            enc: rng.uniform(0.1, 3.0),
            llm: rng.uniform(0.1, 3.0),
        })
        .collect();
    set_max_threads(1);
    let serial = ilp::solve(&items, 3, Duration::from_secs(10));
    set_max_threads(8);
    let parallel = ilp::solve(&items, 3, Duration::from_secs(10));
    set_max_threads(0);
    assert!(serial.optimal && parallel.optimal, "instance too hard for budget");
    assert_eq!(serial.assignment.buckets, parallel.assignment.buckets);
    assert_eq!(
        serial.assignment.c_max().to_bits(),
        parallel.assignment.c_max().to_bits()
    );
}
