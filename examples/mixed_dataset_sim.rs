//! Scenario: the paper's headline experiment — LLaVA-OV training on the
//! heterogeneous mixed dataset (Table 2), DFLOP vs Megatron-LM vs PyTorch
//! on a simulated 4-node HGX A100 cluster (Fig 7 / Fig 13 style).
//!
//!   cargo run --release --offline --example mixed_dataset_sim -- [--nodes 4] [--gbs 128]

use dflop::model::catalog::{llava_ov, llama3, qwen25};
use dflop::sim::{run_system, RunConfig, SystemKind};
use dflop::util::cli::{Args, Spec};
use dflop::util::table::{f, speedup, Table};

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec { valued: vec!["nodes", "gbs", "iters", "seed"], boolean: vec![] };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    let cfg = RunConfig::new(
        args.get_usize("nodes", 4)?,
        args.get_usize("gbs", 128)?,
        args.get_usize("iters", 4)?,
        args.get_u64("seed", 42)?,
    );
    let mut t = Table::new(
        "mixed-dataset training (simulated HGX A100 cluster)",
        &["model", "system", "TFLOP/s per GPU", "iter time (s)", "idle GPU·s", "vs DFLOP"],
    );
    for (label, m) in [
        ("LLaVA-OV (Llama-3 8B)", llava_ov(llama3("8b"))),
        ("LLaVA-OV (Qwen-2.5 72B)", llava_ov(qwen25("72b"))),
    ] {
        let d = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        for (kind, r) in [
            (SystemKind::Dflop, &d),
            (SystemKind::Megatron, &run_system(SystemKind::Megatron, &m, "mixed", &cfg)),
            (SystemKind::Pytorch, &run_system(SystemKind::Pytorch, &m, "mixed", &cfg)),
        ] {
            t.row(vec![
                label.to_string(),
                kind.label().to_string(),
                f(r.per_gpu_throughput / 1e12, 1),
                f(r.mean_iteration_time, 2),
                f(r.mean_idle, 1),
                speedup(d.speedup_over(r)),
            ]);
        }
    }
    t.print();
    Ok(())
}
