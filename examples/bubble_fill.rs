//! Scenario: bubble-filling interleaved execution end to end. Runs the
//! PR-10 acceptance pair — plain DFLOP vs `DflopInterleaved`, which
//! decomposes each microbatch's encoder forward into per-unit sub-ops
//! and packs them into the 1F1B pipeline bubbles — on the video-heavy
//! mixture where encoder work dominates the critical path, then emits
//! the comparison both as tables and as a machine-readable JSON artifact
//! (CI uploads it as `BUBBLE_FILL`).
//!
//! The pair shares one seed, model, and a provably-optimal ILP regime
//! (small batches + a 10 s budget, `lpt_fallbacks == 0` asserted), so
//! every printed gap is exactly reproducible. The example asserts the
//! acceptance claims outright: the plan is unchanged, sub-ops were
//! actually placed, the interleaved mean step is strictly faster, and
//! the mean iteration bubble fraction strictly lower.
//!
//!   cargo run --release --offline --example bubble_fill -- \
//!       [--nodes 2] [--gbs 16] [--iters 4] [--seed 42] \
//!       [--out BUBBLE_FILL.json]

use dflop::model::catalog::{internvl_25, qwen25};
use dflop::obs::bubble::iteration_bubble_fraction;
use dflop::sim::{run_system, RunConfig, RunResult, SystemKind};
use dflop::util::cli::{Args, Spec};
use dflop::util::json::{emit, Json};
use dflop::util::table::{f, speedup, Table};
use std::collections::BTreeMap;
use std::time::Duration;

fn mean_bubble_fraction(r: &RunResult) -> f64 {
    let fracs: Vec<f64> = r.iterations.iter().map(iteration_bubble_fraction).collect();
    fracs.iter().sum::<f64>() / fracs.len().max(1) as f64
}

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let nodes = args.get_usize("nodes", 2)?;
    let gbs = args.get_usize("gbs", 16)?;
    let iters = args.get_usize("iters", 4)?;
    let seed = args.get_u64("seed", 42)?;
    let out_path = args.get_or("out", "BUBBLE_FILL.json");

    let m = internvl_25(qwen25("7b"));
    let mut cfg = RunConfig::new(nodes, gbs, iters, seed);
    cfg.profile_samples = 256;
    cfg.ilp_budget = Duration::from_secs(10);

    let plain = run_system(SystemKind::Dflop, &m, "video", &cfg);
    let inter = run_system(SystemKind::DflopInterleaved, &m, "video", &cfg);

    // The determinism regime: every scheduling call must prove
    // optimality, or the pair would depend on wall-clock budget expiry.
    assert_eq!(plain.lpt_fallbacks, 0, "ILP budget expired — shrink the instance");
    assert_eq!(inter.lpt_fallbacks, 0, "ILP budget expired — shrink the instance");
    // The fill pass reshapes execution, never the plan.
    assert_eq!(inter.theta, plain.theta, "the fill pass changed θ*");

    let mut t = Table::new(
        "bubble filling — plain DFLOP vs interleaved sub-op packing (InternVL-2.5 / Qwen2.5 7B, video)",
        &[
            "iter",
            "plain step (s)",
            "interleaved step (s)",
            "gain",
            "sub-ops",
            "filled GPU.s",
            "bubble frac plain",
            "bubble frac inter",
        ],
    );
    let mut json_iters = Vec::new();
    for (i, (p, x)) in plain.iterations.iter().zip(&inter.iterations).enumerate() {
        let (bp, bx) = (iteration_bubble_fraction(p), iteration_bubble_fraction(x));
        t.row(vec![
            format!("{i}"),
            f(p.iteration_time, 3),
            f(x.iteration_time, 3),
            speedup(p.iteration_time / x.iteration_time),
            format!("{}", x.fills.len()),
            f(x.filled_time(), 3),
            f(bp, 4),
            f(bx, 4),
        ]);
        json_iters.push(Json::obj(vec![
            ("iter", Json::Num(i as f64)),
            ("plain_step_s", Json::Num(p.iteration_time)),
            ("interleaved_step_s", Json::Num(x.iteration_time)),
            ("sub_ops", Json::Num(x.fills.len() as f64)),
            ("filled_gpu_s", Json::Num(x.filled_time())),
            ("bubble_fraction_plain", Json::Num(bp)),
            ("bubble_fraction_interleaved", Json::Num(bx)),
        ]));
    }
    t.print();

    let (bf_plain, bf_inter) = (mean_bubble_fraction(&plain), mean_bubble_fraction(&inter));
    let sub_ops: usize = inter.iterations.iter().map(|s| s.fills.len()).sum();
    let filled: f64 = inter.iterations.iter().map(|s| s.filled_time()).sum();
    println!(
        "mean step: plain {} -> interleaved {} ({}); bubble fraction {} -> {}; {} sub-ops, {} GPU.s packed",
        f(plain.mean_iteration_time, 4),
        f(inter.mean_iteration_time, 4),
        speedup(plain.mean_iteration_time / inter.mean_iteration_time),
        f(bf_plain, 4),
        f(bf_inter, 4),
        sub_ops,
        f(filled, 3),
    );

    // The acceptance claims, asserted so the scenario doubles as a smoke
    // gate in CI: fills were placed, the step strictly improved, and the
    // bubbles strictly shrank.
    assert!(sub_ops > 0, "fill pass never placed a sub-op on the video mixture");
    assert!(
        inter.mean_iteration_time < plain.mean_iteration_time,
        "interleaved did not beat plain: {} vs {}",
        inter.mean_iteration_time,
        plain.mean_iteration_time
    );
    assert!(
        bf_inter < bf_plain,
        "bubble fraction did not shrink: {bf_inter} vs {bf_plain}"
    );

    let arm = |r: &RunResult| {
        Json::obj(vec![
            ("mean_step_s", Json::Num(r.mean_iteration_time)),
            ("tflops_per_gpu", Json::Num(r.per_gpu_throughput / 1e12)),
            ("bubble_fraction", Json::Num(mean_bubble_fraction(r))),
            ("theta", Json::str(format!("{}", r.theta))),
        ])
    };
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("dflop-bubble-fill-v1".into()));
    doc.insert("model".to_string(), Json::Str("internvl-2.5/qwen2.5-7b".into()));
    doc.insert("dataset".to_string(), Json::Str("video".into()));
    doc.insert("nodes".to_string(), Json::Num(nodes as f64));
    doc.insert("gbs".to_string(), Json::Num(gbs as f64));
    doc.insert("iters".to_string(), Json::Num(iters as f64));
    doc.insert("seed".to_string(), Json::Num(seed as f64));
    doc.insert(
        "gain".to_string(),
        Json::Num(plain.mean_iteration_time / inter.mean_iteration_time),
    );
    doc.insert("sub_ops".to_string(), Json::Num(sub_ops as f64));
    doc.insert("filled_gpu_s".to_string(), Json::Num(filled));
    doc.insert("plain_arm".to_string(), arm(&plain));
    doc.insert("interleaved_arm".to_string(), arm(&inter));
    doc.insert("iterations".to_string(), Json::Arr(json_iters));
    std::fs::write(&out_path, emit(&Json::Obj(doc)) + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}
