//! Scenario: sharded data-parallel training — the `shard` subsystem end to
//! end. Runs the per-shard heterogeneity scenarios (graded skew, one
//! laggard, a mid-run hot shard), the all-shards curriculum ramp (one
//! *global* replan for the whole DP group), and the homogeneous control,
//! each under static sharding and under cross-shard rebalancing, and
//! emits the comparison both as a table and as a machine-readable JSON
//! artifact (CI uploads it as `SHARD_BALANCE`).
//!
//!   cargo run --release --offline --example shard_balance -- \
//!       [--nodes 1] [--gbs 64] [--iters 16] [--seed 42] [--dp-shards 4] \
//!       [--out SHARD_BALANCE.json]

use dflop::figures::{shard_grid_with, FigOpts, SHARD_MIN_ITERS};
use dflop::sim::RunResult;
use dflop::util::cli::{Args, Spec};
use dflop::util::json::{emit, Json};
use dflop::util::table::{f, speedup, Table};
use std::collections::BTreeMap;

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "dp-shards", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let o = FigOpts {
        nodes: args.get_usize("nodes", 1)?,
        gbs: args.get_usize("gbs", 64)?,
        iters: args.get_usize("iters", 16)?,
        seed: args.get_u64("seed", 42)?,
    };
    let dp_shards = args.get_usize("dp-shards", 4)?;
    let out_path = args.get_or("out", "SHARD_BALANCE.json");

    let rows = shard_grid_with(&o, dp_shards);

    let mut t = Table::new(
        "shard balance — static sharding vs shard::balance (LLaVA-OV / Llama-3 8B)",
        &[
            "scenario",
            "static step (s)",
            "DFLOP step (s)",
            "gain",
            "gap static (s)",
            "gap DFLOP (s)",
            "migrations",
            "replans",
        ],
    );
    let mut json_rows = Vec::new();
    for (key, stat, rebal) in &rows {
        t.row(vec![
            key.to_string(),
            f(stat.mean_iteration_time, 3),
            f(rebal.mean_iteration_time, 3),
            speedup(stat.mean_iteration_time / rebal.mean_iteration_time),
            f(stat.mean_straggler_gap(), 3),
            f(rebal.mean_straggler_gap(), 3),
            format!("{}", rebal.migrations),
            format!("{}", rebal.replans),
        ]);
        json_rows.push(row_json(key, stat, rebal));
    }
    t.print();

    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("dflop-shard-balance-v1".into()));
    doc.insert("model".to_string(), Json::Str("llava-ov/llama3-8b".into()));
    doc.insert("nodes_per_replica".to_string(), Json::Num(o.nodes as f64));
    doc.insert("dp_shards".to_string(), Json::Num(dp_shards as f64));
    doc.insert("gbs".to_string(), Json::Num(o.gbs as f64));
    doc.insert(
        "iters".to_string(),
        Json::Num(o.iters.max(SHARD_MIN_ITERS) as f64),
    );
    doc.insert("seed".to_string(), Json::Num(o.seed as f64));
    doc.insert("rows".to_string(), Json::Arr(json_rows));
    std::fs::write(&out_path, emit(&Json::Obj(doc)) + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}

fn row_json(scenario: &str, stat: &RunResult, rebal: &RunResult) -> Json {
    let events: Vec<Json> = rebal
        .replan_events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("iteration", Json::Num(e.iteration as f64)),
                ("score", Json::Num(e.stat.score())),
                ("swapped", Json::Bool(e.swapped)),
                ("old_theta", Json::str(format!("{}", e.old))),
                ("new_theta", Json::str(format!("{}", e.new))),
            ])
        })
        .collect();
    let gaps: Vec<Json> = rebal
        .straggler_gaps
        .iter()
        .map(|&g| Json::Num(g))
        .collect();
    Json::obj(vec![
        ("scenario", Json::str(scenario)),
        ("static_step_s", Json::Num(stat.mean_iteration_time)),
        ("rebalanced_step_s", Json::Num(rebal.mean_iteration_time)),
        (
            "gain",
            Json::Num(stat.mean_iteration_time / rebal.mean_iteration_time),
        ),
        ("static_gap_s", Json::Num(stat.mean_straggler_gap())),
        ("rebalanced_gap_s", Json::Num(rebal.mean_straggler_gap())),
        ("static_tflops_per_gpu", Json::Num(stat.per_gpu_throughput / 1e12)),
        (
            "rebalanced_tflops_per_gpu",
            Json::Num(rebal.per_gpu_throughput / 1e12),
        ),
        ("migrations", Json::Num(rebal.migrations as f64)),
        ("replans", Json::Num(rebal.replans as f64)),
        ("theta", Json::str(format!("{}", rebal.theta))),
        ("rebalanced_gaps_s", Json::Arr(gaps)),
        ("events", Json::Arr(events)),
    ])
}
