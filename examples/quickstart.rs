//! Quickstart: the full DFLOP offline + online flow on one workload.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Profiles the model and dataset, runs the Data-aware 3D Parallelism
//! Optimizer (Algorithm 1), schedules one global batch with the hybrid
//! ILP/LPT mechanism, and simulates the resulting iteration against the
//! A100 cluster model — comparing with random microbatching.

use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::pipeline::build::{iterate, SystemPlan};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use dflop::profiling::estimator::Estimator;
use dflop::scheduler::correction::{Correction, CorrectionConfig};
use dflop::scheduler::online::{OnlineScheduler, SchedulerConfig};
use dflop::util::table::secs;

fn main() {
    // 1. The workload: LLaVA-OV (Llama-3 8B) on the Table-2 mixed dataset,
    //    one HGX A100 node.
    let m = llava_ov(llama3("8b"));
    let cluster = ClusterSpec::hgx_a100(1);
    let truth = Truth::new(cluster);
    let gbs = 64;

    // 2. Profiling Engine (§3.2): model grids + dataset statistics.
    let mut backend = SimBackend::new(truth.clone());
    let profile =
        ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(42);
    let data = profile_data(&m, &mut ds, 512);
    println!(
        "profiled {}: mean eff. batch {:.1}, mean packed seq {:.0}",
        profile.model_name,
        data.mean_units(),
        data.mean_seq()
    );

    // 3. Data-aware 3D Parallelism Optimizer (§3.3, Algorithm 1).
    let inp = OptimizerInputs {
        m: &m,
        profile: &profile,
        data: &data,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs,
        assume_balanced: true,
    };
    let plan = optimize(&inp).expect("feasible configuration");
    println!(
        "theta* = {}  (expected makespan {}, {} candidates, {:?})",
        plan.theta,
        secs(plan.expected_makespan),
        plan.candidates_scanned,
        plan.elapsed
    );

    // 4. Online Microbatch Scheduler (§3.4) on one global batch.
    let est = Estimator::new(&m, &profile.throughput);
    let scheduler = OnlineScheduler::new(
        plan.theta,
        SchedulerConfig::default(),
        Correction::new(CorrectionConfig::default()),
    );
    let shapes = ds.shaped_batch(&m, gbs);
    let sched = scheduler.schedule(&est, &shapes);
    println!(
        "scheduled {} items into {} buckets in {} ({:?}, imbalance {:.2}%)",
        gbs,
        sched.assignment.buckets.len(),
        secs(sched.elapsed.as_secs_f64()),
        sched.solver,
        sched.imbalance * 100.0
    );

    // 5. Execute the iteration on the simulated cluster (vs random).
    let sys = SystemPlan { m: &m, truth: &truth, theta: plan.theta };
    let to_buckets = |groups: &Vec<Vec<usize>>| -> Vec<Vec<_>> {
        groups.iter().map(|g| g.iter().map(|&i| shapes[i]).collect()).collect()
    };
    let balanced = iterate(&sys, &to_buckets(&sched.assignment.buckets));
    let mut rng = dflop::util::rng::Rng::new(7);
    let rand = scheduler.schedule_random(&est, &shapes, &mut rng);
    let random = iterate(&sys, &to_buckets(&rand.assignment.buckets));
    println!(
        "iteration time: DFLOP {} vs random {}  ({:.2}x); idle {} vs {}",
        secs(balanced.iteration_time),
        secs(random.iteration_time),
        random.iteration_time / balanced.iteration_time,
        secs(balanced.total_idle()),
        secs(random.total_idle()),
    );
}
