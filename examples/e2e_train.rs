//! End-to-end driver: real multimodal training through the full stack.
//!
//! Proves all three layers compose: the Pallas kernels (L1) lowered inside
//! the JAX model (L2) are loaded as AOT HLO artifacts and driven by the
//! rust coordinator (L3) — python never runs here. The DFLOP online
//! scheduler partitions each global batch of variable-shape items into
//! balanced microbatches (vs the random baseline), and the loss curve of a
//! few hundred real SGD steps is logged.
//!
//! Usage:
//!   cargo run --release --offline --example e2e_train -- \
//!       [--iters 60] [--gbs 12] [--n-mb 3] [--mode balanced|random|both] \
//!       [--lr 0.02] [--seed 42] [--artifacts artifacts]
//!
//! Run `make artifacts` first. Results are recorded in EXPERIMENTS.md.

use dflop::coordinator::{Leader, LeaderConfig, SchedMode};
use dflop::runtime::TrainSession;
use dflop::util::cli::{Args, Spec};
use dflop::util::table::{f, secs, Table};
use std::path::PathBuf;
use std::time::Duration;

fn run_mode(
    artifacts: &PathBuf,
    cfg: &LeaderConfig,
) -> dflop::util::error::Result<dflop::coordinator::LeaderReport> {
    let session = TrainSession::load(artifacts)?;
    eprintln!(
        "loaded {} ({} params, buckets {:?}) on {}",
        session.manifest.config,
        session.manifest.model.total_params,
        session.bucket_shapes(),
        session.platform()
    );
    let mut leader = Leader::new(session, cfg.clone());
    leader.run()
}

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["iters", "gbs", "n-mb", "mode", "lr", "seed", "artifacts"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let iters = args.get_usize("iters", 60)?;
    let base = LeaderConfig {
        gbs: args.get_usize("gbs", 12)?,
        n_mb: args.get_usize("n-mb", 3)?,
        iterations: iters,
        lr: args.get_f64("lr", 0.02)? as f32,
        seed: args.get_u64("seed", 42)?,
        mode: SchedMode::Balanced,
        ilp_budget: Duration::from_millis(20),
    };
    let mode = args.get_or("mode", "both");

    let mut rows: Vec<(String, dflop::coordinator::LeaderReport)> = Vec::new();
    if mode == "balanced" || mode == "both" {
        let mut cfg = base.clone();
        cfg.mode = SchedMode::Balanced;
        rows.push(("DFLOP (balanced)".into(), run_mode(&artifacts, &cfg)?));
    }
    if mode == "random" || mode == "both" {
        let mut cfg = base.clone();
        cfg.mode = SchedMode::Random;
        rows.push(("baseline (random)".into(), run_mode(&artifacts, &cfg)?));
    }

    // Loss curve of the first run (both runs train the same task).
    if let Some((name, rep)) = rows.first() {
        println!("\nloss curve ({name}, {} iterations):", rep.losses.len());
        for (i, chunk) in rep.losses.chunks(10).enumerate() {
            let mean: f32 = chunk.iter().sum::<f32>() / chunk.len() as f32;
            println!(
                "  iters {:>3}-{:>3}: mean loss {:.4}",
                i * 10,
                i * 10 + chunk.len() - 1,
                mean
            );
        }
    }

    let mut t = Table::new(
        "end-to-end training (real PJRT execution)",
        &["scheduler", "mean iter", "sched time", "padding ovh", "final loss"],
    );
    for (name, rep) in &rows {
        t.row(vec![
            name.clone(),
            secs(rep.mean_iter_seconds()),
            secs(
                rep.sched_seconds.iter().sum::<f64>()
                    / rep.sched_seconds.len().max(1) as f64,
            ),
            f(rep.padding_overhead, 3),
            f(rep.final_loss() as f64, 4),
        ]);
    }
    t.print();

    if rows.len() == 2 {
        let speedup = rows[1].1.mean_iter_seconds() / rows[0].1.mean_iter_seconds();
        println!("balanced-vs-random iteration speedup: {speedup:.2}x");
    }
    Ok(())
}
