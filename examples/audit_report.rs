//! Scenario: the explainable-run audit end to end. Runs the adaptive
//! system over the drifting curriculum stream with batch recording on,
//! then emits the full predicted-vs-measured audit: per-iteration
//! estimator residuals bucketed by modality mix and plan epoch, and —
//! for every adopted replan — the counterfactual attribution that
//! re-prices the incumbent θ over the realized post-swap batches via
//! delta replay (no fresh simulations). CI runs this in release mode
//! and uploads `AUDIT_REPORT.json` as an artifact.
//!
//!   cargo run --release --offline --example audit_report -- \
//!       [--nodes 1] [--gbs 48] [--iters 24] [--seed 42] \
//!       [--dataset curriculum] [--out AUDIT_REPORT.json]

use dflop::model::catalog::{llama3, llava_ov};
use dflop::obs::audit::audit_json;
use dflop::obs::ObsConfig;
use dflop::sim::{RunConfig, SystemKind};
use dflop::util::cli::{Args, Spec};
use dflop::util::json::emit;

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "dataset", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let nodes = args.get_usize("nodes", 1)?;
    let gbs = args.get_usize("gbs", 48)?;
    let iters = args.get_usize("iters", 24)?;
    let seed = args.get_u64("seed", 42)?;
    let dataset = args.get_or("dataset", "curriculum");
    let out_path = args.get_or("out", "AUDIT_REPORT.json");

    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(nodes, gbs, iters, seed);
    cfg.obs = Some(ObsConfig { timelines: false, metrics: false, audit: true });

    let r = dflop::engine::run(SystemKind::DflopAdaptive, &m, &dataset, &cfg)?;
    let a = r
        .obs
        .as_deref()
        .and_then(|log| log.audit.as_ref())
        .ok_or_else(|| dflop::err!("audit-enabled run recorded no report"))?;

    println!("dataset       : {dataset} ({iters} iterations, gbs {gbs})");
    println!("theta         : {}", r.theta);
    println!("mean step     : {:.3} s", r.mean_iteration_time);
    println!("audited iters : {}", a.rows.len());
    println!("mean |rel err|: {:.2}%", a.mean_abs_rel_err * 100.0);
    println!("bias          : {:+.4} s", a.bias);
    println!("replans       : {} adopted swaps audited", a.replans.len());
    for ra in &a.replans {
        println!(
            "  swap @ iter {:>3}: incumbent {:.3} s vs adopted {:.3} s over {} iters \
             -> measured {:+.3} s",
            ra.iteration, ra.incumbent_mean, ra.adopted_mean, ra.window, ra.measured_benefit
        );
    }

    std::fs::write(&out_path, emit(&audit_json(a)) + "\n")?;
    println!("report        : -> {out_path}");
    Ok(())
}
