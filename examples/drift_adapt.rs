//! Scenario: online drift detection + adaptive replanning — the `stream`
//! subsystem end to end. Runs the non-stationary workloads (curriculum
//! text→video ramp, bursty video spikes) plus the stationary mixed
//! control, each under a frozen offline θ* and under the drift-adaptive
//! trainer, and emits the comparison both as a table and as a
//! machine-readable JSON artifact (CI uploads it as `DRIFT_ADAPT`).
//!
//!   cargo run --release --offline --example drift_adapt -- \
//!       [--nodes 2] [--gbs 64] [--iters 24] [--seed 42] [--out DRIFT_ADAPT.json]

use dflop::figures::{drift_grid, FigOpts, DRIFT_MIN_ITERS};
use dflop::sim::RunResult;
use dflop::util::cli::{Args, Spec};
use dflop::util::json::{emit, Json};
use dflop::util::table::{f, speedup, Table};
use std::collections::BTreeMap;

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let o = FigOpts {
        nodes: args.get_usize("nodes", 2)?,
        gbs: args.get_usize("gbs", 64)?,
        iters: args.get_usize("iters", 24)?,
        seed: args.get_u64("seed", 42)?,
    };
    let out_path = args.get_or("out", "DRIFT_ADAPT.json");

    let rows = drift_grid(&o);

    let mut t = Table::new(
        "drift adaptation — frozen θ* vs stream::replan (InternVL 2.5 / Qwen-2.5 7B)",
        &["scenario", "frozen (TFLOP/s)", "adaptive (TFLOP/s)", "gain", "replans", "final θ"],
    );
    let mut json_rows = Vec::new();
    for (key, frozen, adaptive) in &rows {
        t.row(vec![
            key.to_string(),
            f(frozen.per_gpu_throughput / 1e12, 1),
            f(adaptive.per_gpu_throughput / 1e12, 1),
            speedup(adaptive.speedup_over(frozen)),
            format!("{}", adaptive.replans),
            format!("{}", adaptive.theta),
        ]);
        json_rows.push(row_json(key, frozen, adaptive));
    }
    t.print();

    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("dflop-drift-adapt-v1".into()));
    doc.insert("model".to_string(), Json::Str("internvl-2.5/qwen-2.5-7b".into()));
    doc.insert("nodes".to_string(), Json::Num(o.nodes as f64));
    doc.insert("gbs".to_string(), Json::Num(o.gbs as f64));
    doc.insert(
        "iters".to_string(),
        Json::Num(o.iters.max(DRIFT_MIN_ITERS) as f64),
    );
    doc.insert("seed".to_string(), Json::Num(o.seed as f64));
    doc.insert("rows".to_string(), Json::Arr(json_rows));
    std::fs::write(&out_path, emit(&Json::Obj(doc)) + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}

fn row_json(scenario: &str, frozen: &RunResult, adaptive: &RunResult) -> Json {
    let swaps: Vec<Json> = adaptive
        .replan_events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("iteration", Json::Num(e.iteration as f64)),
                ("score", Json::Num(e.stat.score())),
                ("quantile_dist", Json::Num(e.stat.quantile_dist)),
                ("mix_tv", Json::Num(e.stat.mix_tv)),
                ("units_dist", Json::Num(e.stat.units_dist)),
                ("swapped", Json::Bool(e.swapped)),
                ("old_theta", Json::str(format!("{}", e.old))),
                ("new_theta", Json::str(format!("{}", e.new))),
                // NaN marks the no-feasible-plan corner; JSON has no NaN,
                // so emit null rather than an unparseable token.
                (
                    "expected_makespan_s",
                    if e.expected_makespan.is_finite() {
                        Json::Num(e.expected_makespan)
                    } else {
                        Json::Null
                    },
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("scenario", Json::str(scenario)),
        ("frozen_tflops_per_gpu", Json::Num(frozen.per_gpu_throughput / 1e12)),
        ("adaptive_tflops_per_gpu", Json::Num(adaptive.per_gpu_throughput / 1e12)),
        ("gain", Json::Num(adaptive.speedup_over(frozen))),
        ("replans", Json::Num(adaptive.replans as f64)),
        ("frozen_theta", Json::str(format!("{}", frozen.theta))),
        ("final_theta", Json::str(format!("{}", adaptive.theta))),
        (
            "frozen_mean_iteration_s",
            Json::Num(frozen.mean_iteration_time),
        ),
        (
            "adaptive_mean_iteration_s",
            Json::Num(adaptive.mean_iteration_time),
        ),
        ("events", Json::Arr(swaps)),
    ])
}
