//! Scenario: cross-modal generalization (paper §5.3.1 / Fig 9) — Qwen2-Audio
//! on an audio-language workload. The audio encoder's final average pool
//! balances encoder/LLM compute, the regime where DFLOP's decoupled
//! parallelism helps most.
//!
//!   cargo run --release --offline --example audio_modality -- [--nodes 4]

use dflop::figures::{fig09, FigOpts};
use dflop::util::cli::{Args, Spec};

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec { valued: vec!["nodes", "gbs", "iters", "seed"], boolean: vec![] };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    let o = FigOpts {
        nodes: args.get_usize("nodes", 4)?,
        gbs: args.get_usize("gbs", 128)?,
        iters: args.get_usize("iters", 4)?,
        seed: args.get_u64("seed", 42)?,
    };
    print!("{}", fig09(&o));
    Ok(())
}
