//! Scenario: cluster-scale sweep (paper §5.3.4 / Fig 12) — how the
//! DFLOP-vs-baseline gap evolves from 1 to 8 measured nodes plus the
//! 16/32-node projection.
//!
//!   cargo run --release --offline --example scalability -- [--gbs 128]

use dflop::figures::{fig12, FigOpts};
use dflop::util::cli::{Args, Spec};

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec { valued: vec!["gbs", "iters", "seed"], boolean: vec![] };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    let o = FigOpts {
        gbs: args.get_usize("gbs", 128)?,
        iters: args.get_usize("iters", 3)?,
        seed: args.get_u64("seed", 42)?,
        ..FigOpts::default()
    };
    print!("{}", fig12(&o));
    Ok(())
}
