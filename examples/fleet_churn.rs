//! Scenario: the fault-injected elastic fleet end to end. Replays each
//! deterministic fault trace (scripted churn, a persistent straggler, a
//! degraded allreduce link, the combined skewed-churn scenario, and the
//! fault-free control) through the same seeded `FaultTrace` in two arms —
//! a static θ* fleet that absorbs the injected physics, and the
//! degradation-aware fleet that re-weights batches off confirmed
//! stragglers and warm-replans for the surviving topology — and emits the
//! comparison both as a table and as a machine-readable JSON artifact
//! (CI uploads it as `FLEET_CHURN`).
//!
//!   cargo run --release --offline --example fleet_churn -- \
//!       [--nodes 1] [--gbs 48] [--iters 18] [--seed 42] [--dp-shards 4] \
//!       [--out FLEET_CHURN.json]

use dflop::figures::{fleet_grid_with, FigOpts, FLEET_MIN_ITERS};
use dflop::sim::RunResult;
use dflop::util::cli::{Args, Spec};
use dflop::util::json::{emit, Json};
use dflop::util::table::{f, speedup, Table};
use std::collections::BTreeMap;

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "dp-shards", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let o = FigOpts {
        nodes: args.get_usize("nodes", 1)?,
        gbs: args.get_usize("gbs", 48)?,
        iters: args.get_usize("iters", 18)?,
        seed: args.get_u64("seed", 42)?,
    };
    let dp_shards = args.get_usize("dp-shards", 4)?;
    let out_path = args.get_or("out", "FLEET_CHURN.json");

    let rows = fleet_grid_with(&o, dp_shards);

    let mut t = Table::new(
        "fleet churn — static θ* vs degradation-aware replanning under the same FaultTrace (LLaVA-OV / Llama-3 8B)",
        &[
            "fault trace",
            "static step (s)",
            "aware step (s)",
            "gain",
            "worst gap static (s)",
            "worst gap aware (s)",
            "fail/rec",
            "degr iters",
            "replans",
        ],
    );
    let worst = |r: &RunResult| r.straggler_gaps.iter().cloned().fold(0.0f64, f64::max);
    let mut json_rows = Vec::new();
    for (trace, dataset, stat, aware) in &rows {
        t.row(vec![
            format!("{trace} ({dataset})"),
            f(stat.mean_iteration_time, 3),
            f(aware.mean_iteration_time, 3),
            speedup(stat.mean_iteration_time / aware.mean_iteration_time),
            f(worst(stat), 3),
            f(worst(aware), 3),
            format!("{}/{}", aware.fault.failures, aware.fault.recoveries),
            format!("{}", aware.fault.degraded_iters),
            format!("{}", aware.replans),
        ]);
        json_rows.push(row_json(trace, dataset, stat, aware));
    }
    t.print();

    // The fault-free control pins the zero-replans guarantee: the
    // degradation-aware machinery must be invisible on a healthy fleet.
    let control = rows
        .iter()
        .find(|(trace, ..)| *trace == "none")
        .expect("none control in the grid");
    assert_eq!(control.3.replans, 0, "fault-free control replanned");

    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("dflop-fleet-churn-v1".into()));
    doc.insert("model".to_string(), Json::Str("llava-ov/llama3-8b".into()));
    doc.insert("nodes_per_replica".to_string(), Json::Num(o.nodes as f64));
    doc.insert("dp_shards".to_string(), Json::Num(dp_shards as f64));
    doc.insert("gbs".to_string(), Json::Num(o.gbs as f64));
    doc.insert(
        "iters".to_string(),
        Json::Num(o.iters.max(FLEET_MIN_ITERS) as f64),
    );
    doc.insert("seed".to_string(), Json::Num(o.seed as f64));
    doc.insert("rows".to_string(), Json::Arr(json_rows));
    std::fs::write(&out_path, emit(&Json::Obj(doc)) + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}

fn row_json(trace: &str, dataset: &str, stat: &RunResult, aware: &RunResult) -> Json {
    let arm = |r: &RunResult| {
        let steps: Vec<Json> = r
            .iterations
            .iter()
            .map(|s| Json::Num(s.iteration_time))
            .collect();
        let gaps: Vec<Json> = r.straggler_gaps.iter().map(|&g| Json::Num(g)).collect();
        let pcts: Vec<Json> = r
            .straggler_gap_percentiles
            .iter()
            .map(|&(q, v)| {
                Json::obj(vec![("q", Json::Num(q)), ("gap_s", Json::Num(v))])
            })
            .collect();
        Json::obj(vec![
            ("mean_step_s", Json::Num(r.mean_iteration_time)),
            ("tflops_per_gpu", Json::Num(r.per_gpu_throughput / 1e12)),
            ("failures", Json::Num(r.fault.failures as f64)),
            ("recoveries", Json::Num(r.fault.recoveries as f64)),
            ("reshard_events", Json::Num(r.fault.reshard_events as f64)),
            ("degraded_iters", Json::Num(r.fault.degraded_iters as f64)),
            ("replans", Json::Num(r.replans as f64)),
            ("theta", Json::str(format!("{}", r.theta))),
            ("step_s", Json::Arr(steps)),
            ("straggler_gaps_s", Json::Arr(gaps)),
            ("gap_percentiles", Json::Arr(pcts)),
        ])
    };
    Json::obj(vec![
        ("fault_trace", Json::str(trace)),
        ("dataset", Json::str(dataset)),
        (
            "gain",
            Json::Num(stat.mean_iteration_time / aware.mean_iteration_time),
        ),
        ("static_arm", arm(stat)),
        ("aware_arm", arm(aware)),
    ])
}
