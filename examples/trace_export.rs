//! Scenario: deterministic run tracing end to end. Runs the acceptance
//! fleet — a 4-shard fleet of single-node replicas replaying the
//! skewed-churn fault trace over skewed shard data — with the recorder
//! fully on, then exports everything the obs subsystem produces: the
//! Chrome trace (replica-tagged op spans, bubble spans, fault/replan
//! instant events; load it in Perfetto or `chrome://tracing`), the
//! metrics registry dump, and the machine-readable run summary. The
//! trace is schema-validated before it is written, and CI uploads it as
//! `TRACE_EXPORT`.
//!
//!   cargo run --release --offline --example trace_export -- \
//!       [--nodes 1] [--gbs 48] [--iters 18] [--seed 42] [--dp-shards 4] \
//!       [--faults skewed-churn] [--out TRACE_EXPORT.json] \
//!       [--metrics-out TRACE_METRICS.json] [--summary-out TRACE_SUMMARY.json]

use dflop::model::catalog::{llama3, llava_ov};
use dflop::obs::chrome::{trace_json, validate_trace};
use dflop::obs::{run_result_json, ObsConfig};
use dflop::shard::ShardConfig;
use dflop::sim::{FaultConfig, RunConfig, SystemKind};
use dflop::util::cli::{Args, Spec};

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec![
            "nodes", "gbs", "iters", "seed", "dp-shards", "faults", "out",
            "metrics-out", "summary-out", "threads",
        ],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let nodes = args.get_usize("nodes", 1)?;
    let gbs = args.get_usize("gbs", 48)?;
    let iters = args.get_usize("iters", 18)?;
    let seed = args.get_u64("seed", 42)?;
    let dp_shards = args.get_usize("dp-shards", 4)?;
    let trace_key = args.get_or("faults", "skewed-churn");
    let out_path = args.get_or("out", "TRACE_EXPORT.json");
    let metrics_path = args.get_or("metrics-out", "TRACE_METRICS.json");
    let summary_path = args.get_or("summary-out", "TRACE_SUMMARY.json");

    let m = llava_ov(llama3("8b"));
    let mut cfg = RunConfig::new(nodes, gbs, iters, seed);
    cfg.shard = Some(ShardConfig {
        dp_shards,
        rebalance: false,
        window_batches: 4,
        ..ShardConfig::default()
    });
    cfg.faults = Some(FaultConfig { trace: trace_key.clone(), respond: true });
    cfg.obs = Some(ObsConfig { timelines: true, metrics: true, audit: false });

    let r = dflop::engine::run(SystemKind::DflopSharded, &m, "skewed-shard", &cfg)?;
    println!("fleet         : {dp_shards} shards × {nodes} node(s), {iters} iterations");
    println!("fault trace   : {trace_key}");
    println!("theta         : {}", r.theta);
    println!("mean step     : {:.3} s", r.mean_iteration_time);
    println!(
        "fault events  : {} failures, {} recoveries, {} reshards",
        r.fault.failures, r.fault.recoveries, r.fault.reshard_events
    );
    println!("replans       : {}", r.replans);

    let log = r.obs.as_ref().expect("recorder was on");
    let trace = trace_json(log);
    validate_trace(&trace).map_err(|e| dflop::err!("trace failed validation: {e}"))?;
    std::fs::write(&out_path, &trace)?;
    println!("trace         : {} events -> {out_path}", log.events.len());

    let reg = log.metrics.as_ref().expect("metrics were on");
    std::fs::write(&metrics_path, reg.dump())?;
    println!(
        "metrics       : {} snapshots -> {metrics_path}",
        reg.snapshots().len()
    );

    std::fs::write(&summary_path, run_result_json(&r))?;
    println!("summary       : -> {summary_path}");
    Ok(())
}
