//! Scenario: heterogeneous per-replica plans — the `engine::hetero` path
//! end to end. Runs the stationary per-shard skew scenarios plus the
//! homogeneous control under *static* sharding, each with one global θ*
//! and with skew-gated per-replica plans, and emits the comparison both
//! as a table and as a machine-readable JSON artifact (CI uploads it as
//! `HETERO_PLAN`).
//!
//!   cargo run --release --offline --example hetero_plan -- \
//!       [--nodes 2] [--gbs 64] [--iters 12] [--seed 42] [--dp-shards 4] \
//!       [--out HETERO_PLAN.json]

use dflop::figures::{hetero_grid_with, FigOpts, HETERO_MIN_ITERS};
use dflop::sim::RunResult;
use dflop::util::cli::{Args, Spec};
use dflop::util::json::{emit, Json};
use dflop::util::table::{f, speedup, Table};
use std::collections::BTreeMap;

fn main() -> dflop::util::error::Result<()> {
    let spec = Spec {
        valued: vec!["nodes", "gbs", "iters", "seed", "dp-shards", "out", "threads"],
        boolean: vec![],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let o = FigOpts {
        nodes: args.get_usize("nodes", 2)?,
        gbs: args.get_usize("gbs", 64)?,
        iters: args.get_usize("iters", 12)?,
        seed: args.get_u64("seed", 42)?,
    };
    let dp_shards = args.get_usize("dp-shards", 4)?;
    let out_path = args.get_or("out", "HETERO_PLAN.json");

    let rows = hetero_grid_with(&o, dp_shards);

    let mut t = Table::new(
        "hetero plans — one global θ* vs per-replica θ (static shards, InternVL 2.5 / Qwen-2.5 7B)",
        &[
            "scenario",
            "global step (s)",
            "hetero step (s)",
            "gain",
            "gap global (s)",
            "gap hetero (s)",
            "fitted",
            "replans",
        ],
    );
    let mut json_rows = Vec::new();
    for (key, global, hetero) in &rows {
        t.row(vec![
            key.to_string(),
            f(global.mean_iteration_time, 3),
            f(hetero.mean_iteration_time, 3),
            speedup(global.mean_iteration_time / hetero.mean_iteration_time),
            f(global.mean_straggler_gap(), 3),
            f(hetero.mean_straggler_gap(), 3),
            format!("{}", hetero.hetero_thetas.len()),
            format!("{}", hetero.replans),
        ]);
        json_rows.push(row_json(key, global, hetero));
    }
    t.print();

    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Str("dflop-hetero-plan-v1".into()));
    doc.insert("model".to_string(), Json::Str("internvl-2.5/qwen-2.5-7b".into()));
    doc.insert("nodes_per_replica".to_string(), Json::Num(o.nodes as f64));
    doc.insert("dp_shards".to_string(), Json::Num(dp_shards as f64));
    doc.insert("gbs".to_string(), Json::Num(o.gbs as f64));
    doc.insert(
        "iters".to_string(),
        Json::Num(o.iters.max(HETERO_MIN_ITERS) as f64),
    );
    doc.insert("seed".to_string(), Json::Num(o.seed as f64));
    doc.insert("rows".to_string(), Json::Arr(json_rows));
    std::fs::write(&out_path, emit(&Json::Obj(doc)) + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}

fn row_json(scenario: &str, global: &RunResult, hetero: &RunResult) -> Json {
    let plans: Vec<Json> = hetero
        .hetero_thetas
        .iter()
        .map(|t| Json::str(format!("{t}")))
        .collect();
    let gaps: Vec<Json> = hetero
        .straggler_gaps
        .iter()
        .map(|&g| Json::Num(g))
        .collect();
    Json::obj(vec![
        ("scenario", Json::str(scenario)),
        ("global_step_s", Json::Num(global.mean_iteration_time)),
        ("hetero_step_s", Json::Num(hetero.mean_iteration_time)),
        (
            "gain",
            Json::Num(global.mean_iteration_time / hetero.mean_iteration_time),
        ),
        ("global_gap_s", Json::Num(global.mean_straggler_gap())),
        ("hetero_gap_s", Json::Num(hetero.mean_straggler_gap())),
        ("global_tflops_per_gpu", Json::Num(global.per_gpu_throughput / 1e12)),
        (
            "hetero_tflops_per_gpu",
            Json::Num(hetero.per_gpu_throughput / 1e12),
        ),
        ("global_theta", Json::str(format!("{}", global.theta))),
        ("per_replica_thetas", Json::Arr(plans)),
        ("replans", Json::Num(hetero.replans as f64)),
        ("hetero_gaps_s", Json::Arr(gaps)),
    ])
}
