"""Synthetic task generator invariants (mirrored by the rust data gen)."""

import numpy as np

from compile import model as M
from compile import task


def test_prototypes_are_distinct():
    protos = [task.prototype(k, 48) for k in range(task.N_KEYS)]
    for i in range(task.N_KEYS):
        for j in range(i + 1, task.N_KEYS):
            assert np.linalg.norm(protos[i] - protos[j]) > 1.0, (i, j)


def test_instance_token_recurrence():
    rng = np.random.default_rng(0)
    cfg = M.SMALL
    _, toks = task.make_instance(rng, cfg, key=3, length=32, t0=100)
    for j in range(1, 32):
        assert toks[j] == (toks[j - 1] + 1 + 3) % cfg.vocab


def test_batch_structure():
    rng = np.random.default_rng(1)
    cfg = M.SMALL
    for n_img, seq in [(1, 128), (2, 256), (4, 512)]:
        patches, tok, seg, img = task.make_batch(rng, cfg, n_img, seq)
        assert patches.shape == (n_img, cfg.tokens_per_image, cfg.patch_dim)
        assert tok.shape == seg.shape == img.shape == (seq,)
        # Segments are contiguous, start at 1, ascend.
        nz = seg[seg != 0]
        assert nz.min() == 1 and nz.max() <= n_img
        changes = np.flatnonzero(np.diff(seg))
        assert len(changes) <= n_img  # contiguous blocks + padding tail
        # img_index consistent with segments.
        for i in range(1, n_img + 1):
            sel = seg == i
            if sel.any():
                assert (img[sel] == i - 1).all()
        assert (img[seg == 0] == n_img).all()
        # Tokens within range.
        assert tok.min() >= 0 and tok.max() < cfg.vocab


def test_batch_keys_vary():
    rng = np.random.default_rng(2)
    cfg = M.SMALL
    # Across many instances the implied keys should cover several values.
    keys = set()
    for _ in range(20):
        _, tok, seg, _ = task.make_batch(rng, cfg, 2, 256)
        for i in (1, 2):
            sel = np.flatnonzero(seg == i)
            if len(sel) >= 2:
                a, b = tok[sel[0]], tok[sel[1]]
                keys.add((int(b) - int(a) - 1) % cfg.vocab)
    assert len(keys) >= 4
    assert all(k < task.N_KEYS for k in keys)
