"""L2 model tests: shapes, learning signal, masking invariants."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile import task


@pytest.fixture(scope="module")
def small_setup():
    cfg = M.SMALL
    params = M.init_params(cfg, 0)
    return cfg, params


def make_batch(cfg, seed=0, n_img=2, seq=256):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(x) for x in task.make_batch(rng, cfg, n_img, seq))


def test_param_count_bands():
    # "small" is a few M params; "base" is ≈100M (the e2e full-size config).
    assert 2e6 < M.count_params(M.SMALL) < 20e6
    assert 80e6 < M.count_params(M.BASE) < 150e6


def test_param_specs_match_init(small_setup):
    cfg, params = small_setup
    for name, shape in M.param_specs(cfg):
        assert params[name].shape == tuple(shape), name
    assert len(params) == len(M.param_specs(cfg))


def test_encoder_output_shape(small_setup):
    cfg, params = small_setup
    patches = jnp.zeros((3, cfg.tokens_per_image, cfg.patch_dim), jnp.float32)
    out = M.encode_images(params, cfg, patches)
    assert out.shape == (3, cfg.hidden)


def test_initial_loss_near_uniform(small_setup):
    cfg, params = small_setup
    batch = make_batch(cfg)
    loss = M.forward_loss(params, cfg, batch)
    # Untrained next-token loss should be within a few nats of ln(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 6.0


def test_loss_decreases_over_steps(small_setup):
    cfg, params = small_setup
    rng = np.random.default_rng(3)
    p = params
    lr = jnp.float32(0.02)
    losses = []
    for _ in range(30):
        batch = tuple(
            jnp.asarray(x) for x in task.make_batch(rng, cfg, 2, 256)
        )
        p, loss = M.train_step(p, cfg, batch, lr)
        losses.append(float(loss))
    early = np.mean(losses[:5])
    late = np.mean(losses[-5:])
    assert late < early - 0.5, f"no learning: {early:.2f} -> {late:.2f}"
    assert np.isfinite(losses).all()


def test_padding_does_not_affect_loss(small_setup):
    # Extending the padded tail with garbage tokens must not change loss.
    cfg, params = small_setup
    patches, tok, seg, img = make_batch(cfg)
    loss_a = float(M.forward_loss(params, cfg, (patches, tok, seg, img)))
    pad = np.asarray(seg) == 0
    tok_b = np.asarray(tok).copy()
    tok_b[pad] = 17  # garbage in padding
    loss_b = float(
        M.forward_loss(params, cfg, (patches, jnp.asarray(tok_b), seg, img))
    )
    np.testing.assert_allclose(loss_a, loss_b, rtol=1e-6)


def test_train_step_is_deterministic(small_setup):
    cfg, params = small_setup
    batch = make_batch(cfg, seed=5)
    p1, l1 = M.train_step(params, cfg, batch, jnp.float32(0.01))
    p2, l2 = M.train_step(params, cfg, batch, jnp.float32(0.01))
    assert float(l1) == float(l2)
    np.testing.assert_array_equal(p1["head_w"], p2["head_w"])


def test_image_conditioning_matters(small_setup):
    # Zeroing the images must change the loss: the model consumes them.
    cfg, params = small_setup
    # Take a few gradient steps first so image pathways carry signal.
    rng = np.random.default_rng(4)
    p = params
    for _ in range(10):
        batch = tuple(jnp.asarray(x) for x in task.make_batch(rng, cfg, 2, 256))
        p, _ = M.train_step(p, cfg, batch, jnp.float32(0.02))
    patches, tok, seg, img = make_batch(cfg, seed=6)
    loss_with = float(M.forward_loss(p, cfg, (patches, tok, seg, img)))
    loss_without = float(
        M.forward_loss(p, cfg, (jnp.zeros_like(patches), tok, seg, img))
    )
    assert abs(loss_with - loss_without) > 1e-4
