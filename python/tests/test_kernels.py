"""L1 kernel correctness: Pallas vs pure-jnp oracle.

The CORE correctness signal of the compile path: a seeded randomized sweep
over shapes, segment layouts, and masking modes (hypothesis is not
installed in this image, so the sweep uses a seeded generator with the same
coverage intent), plus gradient checks through the custom VJPs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels import fused_mlp, packed_attention
from compile.kernels.ref import fused_mlp_ref, packed_attention_ref

RTOL = 2e-5
ATOL = 2e-5


def random_segments(rng, s, max_segments=5, pad_frac=0.25):
    """Contiguous non-zero segments with an optional padded tail."""
    n_pad = int(s * pad_frac * rng.random())
    body = s - n_pad
    n_seg = int(rng.integers(1, max_segments + 1))
    cuts = np.sort(rng.choice(np.arange(1, body), size=n_seg - 1, replace=False)) if n_seg > 1 else np.array([], int)
    seg = np.zeros(s, np.int32)
    bounds = [0, *cuts.tolist(), body]
    for i in range(n_seg):
        seg[bounds[i] : bounds[i + 1]] = i + 1
    return jnp.asarray(seg)


@pytest.mark.parametrize("case", range(12))
def test_attention_matches_ref_random_sweep(case):
    rng = np.random.default_rng(1000 + case)
    h = int(rng.choice([1, 2, 4]))
    s = int(rng.choice([128, 256, 384]))
    d = int(rng.choice([16, 32, 64]))
    causal = bool(rng.integers(0, 2))
    q, k, v = (
        jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32) for _ in range(3)
    )
    seg = random_segments(rng, s)
    out = packed_attention(q, k, v, seg, causal=causal)
    exp = packed_attention_ref(q, k, v, seg, causal=causal)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


def test_attention_all_padding_is_zero():
    rng = np.random.default_rng(7)
    q, k, v = (
        jnp.asarray(rng.standard_normal((2, 128, 32)), jnp.float32) for _ in range(3)
    )
    seg = jnp.zeros(128, jnp.int32)
    out = packed_attention(q, k, v, seg, causal=True)
    np.testing.assert_allclose(out, np.zeros_like(out), atol=1e-6)


def test_attention_single_segment_equals_dense_causal():
    rng = np.random.default_rng(8)
    s = 256
    q, k, v = (
        jnp.asarray(rng.standard_normal((1, s, 32)), jnp.float32) for _ in range(3)
    )
    seg = jnp.ones(s, jnp.int32)
    out = packed_attention(q, k, v, seg, causal=True)
    # Dense causal softmax attention.
    scale = 1.0 / np.sqrt(32.0)
    scores = np.einsum("hqd,hkd->hqk", q, k) * scale
    mask = np.tril(np.ones((s, s), bool))
    scores = np.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(jnp.asarray(scores), axis=-1)
    exp = np.einsum("hqk,hkd->hqd", w, v)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


def test_attention_segments_are_isolated():
    # Changing segment B's content must not affect segment A's output.
    rng = np.random.default_rng(9)
    s = 256
    q = jnp.asarray(rng.standard_normal((2, s, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, 32)), jnp.float32)
    seg = jnp.asarray(np.repeat([1, 2], s // 2), jnp.int32)
    out1 = packed_attention(q, k, v, seg, causal=True)
    k2 = k.at[:, s // 2 :, :].set(0.0)
    v2 = v.at[:, s // 2 :, :].set(9.0)
    out2 = packed_attention(q, k2, v2, seg, causal=True)
    np.testing.assert_allclose(
        out1[:, : s // 2], out2[:, : s // 2], rtol=RTOL, atol=ATOL
    )


def test_attention_gradients_match_ref():
    rng = np.random.default_rng(10)
    h, s, d = 2, 128, 16
    q, k, v = (
        jnp.asarray(rng.standard_normal((h, s, d)), jnp.float32) for _ in range(3)
    )
    seg = random_segments(rng, s)

    def loss_kernel(q, k, v):
        return jnp.sum(packed_attention(q, k, v, seg, causal=True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(packed_attention_ref(q, k, v, seg, causal=True) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("case", range(8))
def test_mlp_matches_ref_random_sweep(case):
    rng = np.random.default_rng(2000 + case)
    t = int(rng.choice([128, 256, 512]))
    h = int(rng.choice([32, 64, 128]))
    f = 4 * h
    x = jnp.asarray(rng.standard_normal((t, h)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((h, f)) * 0.05, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal(f) * 0.01, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((f, h)) * 0.05, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal(h) * 0.01, jnp.float32)
    out = fused_mlp(x, w1, b1, w2, b2)
    exp = fused_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(out, exp, rtol=RTOL, atol=ATOL)


def test_mlp_gradients_match_ref():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((32, 128)) * 0.1, jnp.float32)
    b1 = jnp.zeros(128, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((128, 32)) * 0.1, jnp.float32)
    b2 = jnp.zeros(32, jnp.float32)
    gk = jax.grad(lambda *a: jnp.sum(fused_mlp(*a) ** 2), argnums=(0, 1, 2, 3, 4))(
        x, w1, b1, w2, b2
    )
    gr = jax.grad(
        lambda *a: jnp.sum(fused_mlp_ref(*a) ** 2), argnums=(0, 1, 2, 3, 4)
    )(x, w1, b1, w2, b2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_mlp_block_size_invariance():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((64, 256)) * 0.05, jnp.float32)
    b1 = jnp.zeros(256, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((256, 64)) * 0.05, jnp.float32)
    b2 = jnp.zeros(64, jnp.float32)
    a = fused_mlp(x, w1, b1, w2, b2, block_t=64)
    b = fused_mlp(x, w1, b1, w2, b2, block_t=256)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)
