"""AOT artifact emission: manifest structure and HLO text round-trip."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

PKG_DIR = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--config",
            "small",
            "--buckets",
            "1x128",
            "--enc-grid",
            "1",
            "--llm-grid",
            "128",
            "--out-dir",
            str(out),
        ],
        cwd=PKG_DIR,
        check=True,
    )
    return out


def test_manifest_complete(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    assert manifest["config"] == "small"
    assert manifest["model"]["total_params"] > 1e6
    assert len(manifest["train_steps"]) == 1
    assert manifest["train_steps"][0]["n_img"] == 1
    assert manifest["train_steps"][0]["seq"] == 128
    assert len(manifest["encoder_fwd"]) == 1
    assert len(manifest["llm_fwd"]) == 1
    # Param entries tile the blob exactly.
    offset = 0
    for p in manifest["params"]:
        assert p["offset"] == offset
        n = int(np.prod(p["shape"])) if p["shape"] else 1
        assert p["bytes"] == 4 * n
        offset += p["bytes"]
    blob = (artifacts / manifest["params_file"]).read_bytes()
    assert len(blob) == offset == 4 * manifest["model"]["total_params"]


def test_hlo_text_is_parseable_text(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    for entry in manifest["train_steps"]:
        text = (artifacts / entry["file"]).read_text()
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text


def test_params_blob_values_finite(artifacts):
    manifest = json.loads((artifacts / "manifest.json").read_text())
    blob = np.frombuffer(
        (artifacts / manifest["params_file"]).read_bytes(), dtype="<f4"
    )
    assert np.isfinite(blob).all()
    assert blob.std() > 0.001  # actually initialized, not zeros
