"""AOT compile path: lower the L2 model to HLO *text* artifacts + manifest.

Run once by ``make artifacts``; python never executes at training time.

Outputs (under ``artifacts/``):

- ``train_step_<cfg>_i<n_img>_s<seq>.hlo.txt`` — one SGD step per shape
  bucket. Inputs (in order): every parameter tensor (in ``param_specs``
  order), then ``patches``, ``token_ids``, ``segment_ids``, ``img_index``,
  ``lr``. Outputs: every new parameter tensor, then the scalar loss.
- ``encoder_fwd_<cfg>_i<n>.hlo.txt`` — encoder+connector forward for the
  PJRT profiling backend's effective-batch grid.
- ``llm_fwd_<cfg>_s<seq>.hlo.txt`` — LLM forward for the sequence grid.
- ``params_<cfg>.bin`` — initial parameters, concatenated f32
  little-endian in spec order.
- ``manifest.json`` — shapes, offsets, bucket list, task constants; parsed
  by ``rust/src/runtime/artifacts.rs``.

Interchange is HLO text, NOT ``.serialize()``: jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import task


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg, specs, n_img, seq):
    names = [n for n, _ in specs]

    def step_fn(*args):
        n = len(names)
        params = dict(zip(names, args[:n]))
        patches, token_ids, segment_ids, img_index, lr = args[n:]
        new_params, loss = M.train_step(
            params, cfg, (patches, token_ids, segment_ids, img_index), lr
        )
        return tuple(new_params[name] for name in names) + (loss,)

    arg_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in specs
    ] + [
        jax.ShapeDtypeStruct((n_img, cfg.tokens_per_image, cfg.patch_dim), jnp.float32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    return to_hlo_text(jax.jit(step_fn).lower(*arg_specs))


def lower_encoder_fwd(cfg, specs, n_img):
    names = [n for n, _ in specs]

    def fwd(*args):
        params = dict(zip(names, args[: len(names)]))
        return (M.encoder_forward(params, cfg, args[len(names)]),)

    arg_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs] + [
        jax.ShapeDtypeStruct((n_img, cfg.tokens_per_image, cfg.patch_dim), jnp.float32)
    ]
    return to_hlo_text(jax.jit(fwd).lower(*arg_specs))


def lower_llm_fwd(cfg, specs, seq):
    names = [n for n, _ in specs]

    def fwd(*args):
        params = dict(zip(names, args[: len(names)]))
        token_ids, segment_ids, img_index, visual = args[len(names):]
        return (M.llm_forward(params, cfg, token_ids, segment_ids, img_index, visual),)

    arg_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs] + [
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((seq,), jnp.int32),
        jax.ShapeDtypeStruct((1, cfg.hidden), jnp.float32),
    ]
    return to_hlo_text(jax.jit(fwd).lower(*arg_specs))


def parse_buckets(spec: str):
    out = []
    for part in spec.split(","):
        n, s = part.strip().split("x")
        out.append((int(n), int(s)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="small", choices=["small", "base"])
    ap.add_argument("--buckets", default="2x256,4x512")
    ap.add_argument("--enc-grid", default="1,2,4")
    ap.add_argument("--llm-grid", default="128,256,512")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = M.config_by_name(args.config)
    specs = M.param_specs(cfg)
    os.makedirs(args.out_dir, exist_ok=True)
    buckets = parse_buckets(args.buckets)
    enc_grid = [int(x) for x in args.enc_grid.split(",")]
    llm_grid = [int(x) for x in args.llm_grid.split(",")]

    # ---- initial parameters ----
    params = M.init_params(cfg, args.seed)
    param_entries = []
    offset = 0
    blob = bytearray()
    for name, shape in specs:
        arr = np.asarray(params[name], dtype=np.float32)
        assert arr.shape == tuple(shape), (name, arr.shape, shape)
        raw = arr.tobytes()  # little-endian f32 on all supported hosts
        param_entries.append(
            {"name": name, "shape": list(shape), "offset": offset, "bytes": len(raw)}
        )
        blob.extend(raw)
        offset += len(raw)
    params_file = f"params_{args.config}.bin"
    with open(os.path.join(args.out_dir, params_file), "wb") as f:
        f.write(bytes(blob))

    # ---- train_step per bucket ----
    bucket_entries = []
    for n_img, seq in buckets:
        text = lower_train_step(cfg, specs, n_img, seq)
        fname = f"train_step_{args.config}_i{n_img}_s{seq}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        bucket_entries.append({"n_img": n_img, "seq": seq, "file": fname})
        print(f"wrote {fname} ({len(text) / 1e6:.1f} MB)")

    # ---- profiling forward passes ----
    enc_entries = []
    for n in enc_grid:
        text = lower_encoder_fwd(cfg, specs, n)
        fname = f"encoder_fwd_{args.config}_i{n}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        enc_entries.append({"n_img": n, "file": fname})
    llm_entries = []
    for s in llm_grid:
        text = lower_llm_fwd(cfg, specs, s)
        fname = f"llm_fwd_{args.config}_s{s}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        llm_entries.append({"seq": s, "file": fname})

    manifest = {
        "config": args.config,
        "model": {
            "vocab": cfg.vocab,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "enc_layers": cfg.enc_layers,
            "llm_layers": cfg.llm_layers,
            "mlp_ratio": cfg.mlp_ratio,
            "tokens_per_image": cfg.tokens_per_image,
            "patch_dim": cfg.patch_dim,
            "total_params": M.count_params(cfg),
        },
        "task": {"n_keys": task.N_KEYS, "noise": task.NOISE},
        "params_file": params_file,
        "params": param_entries,
        "train_steps": bucket_entries,
        "encoder_fwd": enc_entries,
        "llm_fwd": llm_entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(
        f"manifest.json: {M.count_params(cfg):,} params, "
        f"{len(bucket_entries)} train buckets"
    )


if __name__ == "__main__":
    main()
