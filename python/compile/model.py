"""Layer-2: a real (small) multimodal LLM in JAX, built on the L1 kernels.

Architecture (a miniature of the paper's encoder → connector → LLM stack):

- **Encoder**: linear patch embedding + transformer blocks over the packed
  per-image token sequence (non-causal, segment-masked so images never
  attend across each other), using `kernels.packed_attention` and
  `kernels.fused_mlp`.
- **Connector**: mean-pool each image's tokens + linear projection — the
  token-reducing connector family of §2.1.
- **LLM**: token embedding + per-token visual conditioning (each text token
  receives its image's connector output), causal segment-masked decoder
  blocks on the *packed* sequence (batch = 1, §3.2.1), LM head.
- **Loss**: next-token cross-entropy within segments.
- **train_step**: SGD on all parameters; returns (new_params, loss).

Everything is shape-static per (n_images, seq_len) bucket; `aot.py` lowers
`train_step` once per bucket to HLO text for the rust runtime. Python never
runs at training time.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from compile.kernels import fused_mlp, packed_attention


class ModelConfig(NamedTuple):
    vocab: int = 512
    hidden: int = 256
    heads: int = 4
    enc_layers: int = 2
    llm_layers: int = 4
    mlp_ratio: int = 4
    # Patch grid per image: tokens_per_image patches of patch_dim floats.
    tokens_per_image: int = 16
    patch_dim: int = 48


SMALL = ModelConfig()
# ≈100M parameters: the e2e example's "full-size" configuration.
BASE = ModelConfig(
    vocab=4096,
    hidden=768,
    heads=12,
    enc_layers=4,
    llm_layers=12,
    tokens_per_image=16,
    patch_dim=48,
)


def config_by_name(name: str) -> ModelConfig:
    return {"small": SMALL, "base": BASE}[name]


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """Ordered (name, shape) list — the rust runtime relies on this order."""
    h, f = cfg.hidden, cfg.hidden * cfg.mlp_ratio
    specs = [("enc_patch_w", (cfg.patch_dim, h)), ("enc_patch_b", (h,))]
    for i in range(cfg.enc_layers):
        specs += _block_specs(f"enc_{i}", h, f)
    specs += [("conn_w", (h, h)), ("conn_b", (h,))]
    specs += [("tok_embed", (cfg.vocab, h))]
    for i in range(cfg.llm_layers):
        specs += _block_specs(f"llm_{i}", h, f)
    specs += [("head_w", (h, cfg.vocab)), ("head_b", (cfg.vocab,))]
    return specs


def _block_specs(prefix, h, f):
    return [
        (f"{prefix}_ln1_g", (h,)),
        (f"{prefix}_ln1_b", (h,)),
        (f"{prefix}_wqkv", (h, 3 * h)),
        (f"{prefix}_wo", (h, h)),
        (f"{prefix}_ln2_g", (h,)),
        (f"{prefix}_ln2_b", (h,)),
        (f"{prefix}_w1", (h, f)),
        (f"{prefix}_b1", (f,)),
        (f"{prefix}_w2", (f, h)),
        (f"{prefix}_b2", (h,)),
    ]


def init_params(cfg: ModelConfig, seed: int = 0):
    """He-style init; returns a dict in `param_specs` order."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "_b1", "_b2", "ln1_b", "ln2_b")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(("ln1_g", "ln2_g")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * scale
            )
    return params


def count_params(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_specs(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _block(params, prefix, x, segment_ids, heads, causal):
    """Pre-norm transformer block on a packed (S, H) sequence."""
    s, h = x.shape
    d = h // heads
    y = _layer_norm(x, params[f"{prefix}_ln1_g"], params[f"{prefix}_ln1_b"])
    qkv = y @ params[f"{prefix}_wqkv"]  # (S, 3H)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    # (S, H) -> (heads, S, d)
    to_heads = lambda t: t.reshape(s, heads, d).transpose(1, 0, 2)
    attn = packed_attention(
        to_heads(q), to_heads(k), to_heads(v), segment_ids, causal=causal
    )
    attn = attn.transpose(1, 0, 2).reshape(s, h)
    x = x + attn @ params[f"{prefix}_wo"]
    y = _layer_norm(x, params[f"{prefix}_ln2_g"], params[f"{prefix}_ln2_b"])
    x = x + fused_mlp(
        y,
        params[f"{prefix}_w1"],
        params[f"{prefix}_b1"],
        params[f"{prefix}_w2"],
        params[f"{prefix}_b2"],
    )
    return x


def encode_images(params, cfg: ModelConfig, patches):
    """Encoder + connector.

    Args:
      patches: ``(n_img, tokens_per_image, patch_dim)``.

    Returns:
      ``(n_img, hidden)`` visual embeddings.
    """
    n_img, t, p = patches.shape
    x = patches.reshape(n_img * t, p) @ params["enc_patch_w"] + params["enc_patch_b"]
    # One segment per image; no padding segments on the encoder side.
    seg = jnp.repeat(jnp.arange(1, n_img + 1, dtype=jnp.int32), t)
    for i in range(cfg.enc_layers):
        x = _block(params, f"enc_{i}", x, seg, cfg.heads, causal=False)
    pooled = x.reshape(n_img, t, cfg.hidden).mean(axis=1)
    return pooled @ params["conn_w"] + params["conn_b"]


def forward_loss(params, cfg: ModelConfig, batch):
    """Packed-sequence next-token loss.

    `batch` fields (shape-static per bucket):
      patches:     (n_img, tokens_per_image, patch_dim) f32
      token_ids:   (S,) i32
      segment_ids: (S,) i32, 0 = padding
      img_index:   (S,) i32 — index into the image list for each token
                   (n_img, a zero row, for tokens without an image).
    """
    patches, token_ids, segment_ids, img_index = batch
    visual = encode_images(params, cfg, patches)
    # Row n_img is a zero "no image" embedding.
    visual = jnp.concatenate([visual, jnp.zeros((1, cfg.hidden), visual.dtype)])
    x = params["tok_embed"][token_ids] + visual[img_index]
    for i in range(cfg.llm_layers):
        x = _block(params, f"llm_{i}", x, segment_ids, cfg.heads, causal=True)
    logits = x @ params["head_w"] + params["head_b"]  # (S, V)

    # Next-token targets within segments.
    targets = jnp.roll(token_ids, -1)
    same_seg = jnp.roll(segment_ids, -1) == segment_ids
    valid = (segment_ids != 0) & same_seg
    valid = valid.at[-1].set(False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(valid.sum(), 1)
    return (nll * valid).sum() / denom


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(params, cfg: ModelConfig, batch, lr):
    """One SGD step with global-norm gradient clipping at 1.0."""
    loss, grads = jax.value_and_grad(forward_loss)(params, cfg, batch)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads))
    )
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-8))
    new_params = jax.tree_util.tree_map(
        lambda p, g: p - lr * scale * g, params, grads
    )
    return new_params, loss


# Module-level fwd-only entry points for the PJRT profiling artifacts.
def encoder_forward(params, cfg: ModelConfig, patches):
    return encode_images(params, cfg, patches)


def llm_forward(params, cfg: ModelConfig, token_ids, segment_ids, img_index, visual):
    visual = jnp.concatenate([visual, jnp.zeros((1, cfg.hidden), visual.dtype)])
    x = params["tok_embed"][token_ids] + visual[img_index]
    for i in range(cfg.llm_layers):
        x = _block(params, f"llm_{i}", x, segment_ids, cfg.heads, causal=True)
    return x @ params["head_w"] + params["head_b"]
