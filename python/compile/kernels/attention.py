"""Packed varlen flash attention as a Pallas kernel (Layer 1).

The paper's §3.2.1 observation — under sequence packing, *linear* layer cost
depends on the packed total while *attention* cost depends on individual
instance lengths — is realized here as a segment-masked flash kernel: one
kernel serves any packing, the segment-id mask confines attention (and its
cost structure) to instances.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): instead of CUDA varlen
index arithmetic (cu_seqlens) the TPU-style kernel tiles Q into MXU-aligned
VMEM blocks, iterates KV blocks in an online-softmax loop (running max +
normalizer), and masks by segment id — no S×S score tensor ever exists in
HBM.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO so the AOT
artifacts run on the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, seg_ref, o_ref, *, block_k, causal,
                      block_q, seq_len):
    """One (head, q-block) grid cell.

    Block shapes:
      q_ref:   (block_q, D)   — the Q tile in VMEM
      k_ref:   (S, D)         — full K for this head (S ≤ a few K tokens)
      v_ref:   (S, D)
      seg_ref: (S,)           — segment ids (shared across heads)
      o_ref:   (block_q, D)
    """
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    seg_q = seg_ref[pl.dslice(iq * block_q, block_q)]

    n_kv = seq_len // block_k

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k_blk = k_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        seg_k = seg_ref[pl.dslice(j * block_k, block_k)]
        s = q @ k_blk.T * scale  # (block_q, block_k)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = (seg_q[:, None] == seg_k[None, :]) & (seg_q[:, None] != 0)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask, s, NEG_INF)
        # Online softmax update.
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # Rows where everything is masked: keep p at 0.
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    out = acc / jnp.where(l > 0.0, l, 1.0)[:, None]
    o_ref[...] = out.astype(o_ref.dtype)


def _attention_fwd_impl(q, k, v, segment_ids, causal, block_q, block_k):
    """Launch the Pallas kernel (forward only)."""
    h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (
        f"seq {s} not a multiple of blocks ({block_q}, {block_k})"
    )
    kernel = functools.partial(
        _attention_kernel,
        block_k=block_k,
        causal=causal,
        block_q=block_q,
        seq_len=s,
    )
    grid = (h, s // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((None, s, d), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((None, s, d), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((s,), lambda ih, iq: (0,)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,
    )(q, k, v, segment_ids)


def _ref_attention(q, k, v, segment_ids, causal):
    """Dense formulation used only to derive the backward pass (the flash
    kernel runs forward; the VJP is the standard recompute-based gradient
    expressed in XLA ops — the common fwd-kernel + XLA-bwd split)."""
    s = q.shape[1]
    d = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    seg_q = segment_ids[:, None]
    seg_k = segment_ids[None, :]
    mask = (seg_q == seg_k) & (seg_q != 0)
    if causal:
        pos = jnp.arange(s)
        mask = mask & (pos[:, None] >= pos[None, :])
    scores = jnp.where(mask[None], scores, NEG_INF)
    weights = jax.nn.softmax(scores, axis=-1)
    valid = mask.any(axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", weights, v)
    return jnp.where(valid[None, :, None], out, 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _packed_attention_core(q, k, v, segment_ids, causal, block_q, block_k):
    return _attention_fwd_impl(q, k, v, segment_ids, causal, block_q, block_k)


def _core_fwd(q, k, v, segment_ids, causal, block_q, block_k):
    out = _attention_fwd_impl(q, k, v, segment_ids, causal, block_q, block_k)
    return out, (q, k, v, segment_ids)


def _core_bwd(causal, block_q, block_k, residuals, g):
    q, k, v, segment_ids = residuals
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref_attention(q_, k_, v_, segment_ids, causal),
        q,
        k,
        v,
    )
    dq, dk, dv = vjp(g)
    import numpy as np

    dseg = np.zeros(segment_ids.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, dseg


_packed_attention_core.defvjp(_core_fwd, _core_bwd)


def packed_attention(q, k, v, segment_ids, causal=True, block_q=128, block_k=128):
    """Segment-masked flash attention over a packed sequence.

    Args:
      q, k, v: ``(H, S, D)``; S must be a multiple of the block sizes
        (callers pad to the AOT shape buckets anyway).
      segment_ids: ``(S,)`` int32, 0 = padding.
      causal: causal masking within segments (True for the LLM tower,
        False for the encoder).

    Returns:
      ``(H, S, D)``, zeros at padding rows. Differentiable in q, k, v.
    """
    s = q.shape[1]
    return _packed_attention_core(
        q, k, v, segment_ids, causal, min(block_q, s), min(block_k, s)
    )
