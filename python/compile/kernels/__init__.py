"""Layer-1 Pallas kernels (interpret mode) and their pure-jnp oracles."""
from .attention import packed_attention
from .mlp import fused_mlp
from . import ref
