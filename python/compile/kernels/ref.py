"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its oracle to float32 tolerance across the pytest shape sweep
(`python/tests/test_kernels.py`). They are deliberately written in the most
obvious dense form — O(S²) score materialization, unfused MLP — so a reviewer
can audit them at a glance.
"""

import jax.numpy as jnp
from jax.nn import gelu, softmax

NEG_INF = -1e30


def packed_attention_ref(q, k, v, segment_ids, causal=True):
    """Dense reference for packed varlen attention.

    Args:
      q, k, v: ``(H, S, D)`` arrays.
      segment_ids: ``(S,)`` int32; 0 marks padding, equal non-zero ids mark
        tokens of the same packed instance. Attention never crosses segment
        boundaries (the paper's §3.2.1: attention must "process each original
        instance separately to maintain causal integrity").
      causal: apply a causal mask within each segment (LLM side). The
        encoder side uses ``causal=False``.

    Returns:
      ``(H, S, D)`` attention output; padding rows are zero.
    """
    h, s, d = q.shape
    del h
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    seg_q = segment_ids[:, None]
    seg_k = segment_ids[None, :]
    mask = (seg_q == seg_k) & (seg_q != 0)
    if causal:
        pos = jnp.arange(s)
        mask = mask & (pos[:, None] >= pos[None, :])
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    weights = softmax(scores, axis=-1)
    # Rows with no valid key (padding) would be uniform after softmax over
    # NEG_INF; zero them explicitly.
    valid_row = mask.any(axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", weights, v)
    return jnp.where(valid_row[None, :, None], out, 0.0)


def fused_mlp_ref(x, w1, b1, w2, b2):
    """Dense reference for the fused MLP: ``gelu(x @ w1 + b1) @ w2 + b2``."""
    hidden = gelu(x @ w1 + b1, approximate=True)
    return hidden @ w2 + b2
