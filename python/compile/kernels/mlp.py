"""Fused transformer MLP as a Pallas kernel (Layer 1).

``gelu(x @ w1 + b1) @ w2 + b2`` with the (4×hidden) intermediate activation
kept entirely in VMEM: the kernel tiles the packed token dimension into
MXU-aligned blocks and runs up-projection, activation, and down-projection
inside one grid cell, so the intermediate never round-trips HBM — the
TPU analogue of the fused-MLP CUDA kernels the paper's throughput profile
attributes its "linear path" to (§3.2.1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.nn import gelu


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = gelu(x @ w1_ref[...] + b1_ref[...][None, :], approximate=True)
    o_ref[...] = (h @ w2_ref[...] + b2_ref[...][None, :]).astype(o_ref.dtype)


def _mlp_fwd_impl(x, w1, b1, w2, b2, block_t):
    """Fused MLP over packed tokens.

    Args:
      x: ``(T, H)`` packed token activations; T must divide by ``block_t``
        (AOT shape buckets are multiples of 128).
      w1: ``(H, F)``; b1: ``(F,)``; w2: ``(F, H)``; b2: ``(H,)``.

    Returns:
      ``(T, H)``.
    """
    t, h = x.shape
    f = w1.shape[1]
    block_t = min(block_t, t)
    assert t % block_t == 0, f"tokens {t} not a multiple of block {block_t}"
    grid = (t // block_t,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, h), lambda i: (i, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def _ref_mlp(x, w1, b1, w2, b2):
    return gelu(x @ w1 + b1, approximate=True) @ w2 + b2


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _fused_mlp_core(x, w1, b1, w2, b2, block_t):
    return _mlp_fwd_impl(x, w1, b1, w2, b2, block_t)


def _core_fwd(x, w1, b1, w2, b2, block_t):
    return _mlp_fwd_impl(x, w1, b1, w2, b2, block_t), (x, w1, b1, w2, b2)


def _core_bwd(block_t, residuals, g):
    x, w1, b1, w2, b2 = residuals
    _, vjp = jax.vjp(_ref_mlp, x, w1, b1, w2, b2)
    return vjp(g)


_fused_mlp_core.defvjp(_core_fwd, _core_bwd)


def fused_mlp(x, w1, b1, w2, b2, block_t=128):
    """Fused MLP over packed tokens (Pallas forward, XLA backward).

    Args:
      x: ``(T, H)`` packed token activations.
      w1: ``(H, F)``; b1: ``(F,)``; w2: ``(F, H)``; b2: ``(H,)``.

    Returns:
      ``(T, H)``; differentiable in all five operands.
    """
    return _fused_mlp_core(x, w1, b1, w2, b2, min(block_t, x.shape[0]))
