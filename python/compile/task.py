"""Synthetic multimodal captioning task for the end-to-end example.

Each instance is an (image, token sequence) pair where the sequence is only
predictable if the model reads the image: the image carries a hidden *key*
``k ∈ [0, N_KEYS)`` (its patches are noise around prototype ``k``), and the
text follows ``t[j+1] = (t[j] + 1 + k) mod vocab``. A model that learns to
decode the key from the connector output drives the next-token loss toward
zero; one that ignores images plateaus at ``ln(N_KEYS)`` above it.

The prototype construction is a closed formula (no RNG) so the rust-side
data generator (`examples/e2e_train.rs`) reproduces the same distribution
without sharing random state with python.
"""

import numpy as np

N_KEYS = 8
NOISE = 0.5


def prototype(key: int, patch_dim: int) -> np.ndarray:
    """Deterministic prototype direction for a key (same formula in rust)."""
    j = np.arange(patch_dim, dtype=np.float64)
    return np.sin(0.1 + 1.7 * key + 0.37 * j).astype(np.float32)


def make_instance(rng, cfg, key: int, length: int, t0: int):
    """One instance: patches (tokens_per_image, patch_dim) + token list."""
    proto = prototype(key, cfg.patch_dim)
    patches = proto[None, :] + NOISE * rng.standard_normal(
        (cfg.tokens_per_image, cfg.patch_dim)
    ).astype(np.float32)
    toks = np.empty(length, dtype=np.int32)
    toks[0] = t0 % cfg.vocab
    for j in range(1, length):
        toks[j] = (toks[j - 1] + 1 + key) % cfg.vocab
    return patches, toks


def make_batch(rng, cfg, n_img: int, seq: int):
    """A packed batch for one (n_img, seq) shape bucket.

    Returns (patches, token_ids, segment_ids, img_index) with
    patches ``(n_img, T, P)`` and the three ``(seq,)`` int32 vectors.
    """
    per = seq // n_img
    patches = np.zeros((n_img, cfg.tokens_per_image, cfg.patch_dim), np.float32)
    token_ids = np.zeros(seq, np.int32)
    segment_ids = np.zeros(seq, np.int32)
    img_index = np.full(seq, n_img, np.int32)  # n_img = the zero row
    pos = 0
    for i in range(n_img):
        # Variable instance lengths (multiples of 1, ≥ 8) within the bucket.
        length = per if i < n_img - 1 else seq - pos
        length = max(8, length - int(rng.integers(0, per // 4 + 1)))
        length = min(length, seq - pos)
        key = int(rng.integers(0, N_KEYS))
        p, toks = make_instance(rng, cfg, key, length, int(rng.integers(0, cfg.vocab)))
        patches[i] = p
        token_ids[pos : pos + length] = toks
        segment_ids[pos : pos + length] = i + 1
        img_index[pos : pos + length] = i
        pos += length
    return patches, token_ids, segment_ids, img_index
